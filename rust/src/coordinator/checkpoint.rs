//! Mid-trial checkpoint state: a consistent cut of a running simulation at
//! a communication-round boundary.
//!
//! The paper's premise is tolerating *worker* failure mid-training; this
//! module is the harness-level mirror — tolerating failure of the harness
//! itself mid-*trial*. Following Zhang's EASGD treatment (the elastic
//! center θ̃ is the durable state of the system), a [`RunCheckpoint`]
//! captures exactly what a round boundary owns:
//!
//!  * the master aggregate θ̃, per-worker sync stats and the policy's
//!    cross-sync state ([`MasterState::snapshot`](crate::coordinator::master::MasterState::snapshot));
//!  * every worker replica θ with its optimizer state, miss counter,
//!    score-tracker ring, probe RNG and batcher cursor
//!    ([`WorkerState::snapshot`](crate::coordinator::worker::WorkerState::snapshot));
//!  * the gossip board entries (stamp round + estimate per worker);
//!  * engine-internal noise RNG streams and the driver's own RNG streams;
//!  * the metric log and per-round sync counts accumulated so far (the
//!    virtual clock is replayed from the counts on completion).
//!
//! All floating-point payloads are hex bit-blobs (`util::bits`), so a
//! restore continues **bit-identically** on engines without host-anchored
//! timing (the quadratic engine — pinned by `tests/checkpoint_resume.rs`).
//! A checkpoint is driver-specific: the sequential driver shares one
//! engine and two RNG streams, the threaded driver keeps them per thread,
//! so each driver validates the `driver` tag before restoring.

use crate::metrics::MetricsLog;
use crate::util::bits;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};

/// Format version of the checkpoint payload itself (bumped when the state
/// layout changes; a mismatch invalidates the checkpoint, never the
/// committed records around it).
pub const CHECKPOINT_VERSION: u64 = 1;

/// Driver tag of the sequential simulator.
pub const DRIVER_SEQUENTIAL: &str = "sequential";
/// Driver tag of the threaded simulator.
pub const DRIVER_THREADED: &str = "threaded";

/// Full simulator state at a round boundary. See the module docs.
#[derive(Clone, Debug)]
pub struct RunCheckpoint {
    /// [`DRIVER_SEQUENTIAL`] or [`DRIVER_THREADED`] — a checkpoint only
    /// restores into the driver that wrote it (the config's `threaded`
    /// flag is part of the trial fingerprint, so this never mixes in
    /// practice; the tag makes it a hard error instead of a silent one).
    pub driver: String,
    /// First round the resumed run executes.
    pub next_round: u64,
    /// `MasterState::snapshot` payload.
    pub master: Json,
    /// One `WorkerState::snapshot` payload per worker, index-ordered.
    pub workers: Vec<Json>,
    /// Gossip board content: (stamp round, θ estimate) per worker.
    pub gossip: Vec<(u64, Vec<f32>)>,
    /// Engine-internal state. Sequential: `{"all": ...}` (one shared
    /// engine). Threaded: `{"master": ..., "workers": [...]}`.
    pub engines: Json,
    /// Driver RNG streams. Sequential: `{"order": ..., "gossip": ...}`
    /// (gossip sync mode: `{"order": ...}` only — no peer-estimate stream).
    /// Threaded: `{"gossip": [per-worker states]}` (no order stream;
    /// gossip sync mode: empty).
    pub rngs: Json,
    /// Sync-topology state. Central mode: `Null`. Gossip mode:
    /// `{"mode": "gossip", "master_slot": {round, theta}, "pull_cursors":
    /// [...], "worker_policies": [...]}` — the master's published snapshot
    /// slot, each worker's last-pulled stamp, and the per-worker policy
    /// instances' cross-sync state. The tag makes a cross-mode resume a
    /// hard error instead of a silently wrong continuation.
    pub sync: Json,
    /// Metric log accumulated so far.
    pub log: MetricsLog,
    /// Served-sync count of every completed round (virtual-clock replay).
    pub per_round_syncs: Vec<usize>,
}

impl RunCheckpoint {
    /// The sync topology this checkpoint was cut under, decoded from the
    /// `sync` payload (`Null` = central, the pre-gossip encoding).
    pub fn sync_mode(&self) -> crate::config::SyncMode {
        if self.sync.get("mode").as_str() == Some("gossip") {
            crate::config::SyncMode::Gossip
        } else {
            crate::config::SyncMode::Central
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", Json::num(CHECKPOINT_VERSION as f64)),
            ("driver", Json::str(&self.driver)),
            ("next_round", Json::num(self.next_round as f64)),
            ("master", self.master.clone()),
            ("workers", Json::Arr(self.workers.clone())),
            (
                "gossip",
                Json::Arr(
                    self.gossip
                        .iter()
                        .map(|(round, theta)| {
                            Json::obj(vec![
                                ("round", Json::num(*round as f64)),
                                ("theta", Json::str(&bits::f32s_hex(theta))),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("engines", self.engines.clone()),
            ("rngs", self.rngs.clone()),
            ("records", self.log.to_json()),
            (
                "per_round_syncs",
                Json::Arr(self.per_round_syncs.iter().map(|&s| Json::num(s as f64)).collect()),
            ),
        ];
        // Omitted for central-mode checkpoints, so the pre-gossip payload
        // encoding (and its canonical fixed point) is unchanged.
        if self.sync != Json::Null {
            fields.push(("sync", self.sync.clone()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<RunCheckpoint> {
        let version = j.get("version").as_f64().context("checkpoint: missing 'version'")? as u64;
        ensure!(
            version == CHECKPOINT_VERSION,
            "checkpoint format v{version}, this build reads v{CHECKPOINT_VERSION}"
        );
        let driver = j
            .get("driver")
            .as_str()
            .context("checkpoint: missing 'driver'")?
            .to_string();
        ensure!(
            driver == DRIVER_SEQUENTIAL || driver == DRIVER_THREADED,
            "checkpoint: unknown driver '{driver}'"
        );
        let gossip = j
            .get("gossip")
            .as_arr()
            .context("checkpoint: missing 'gossip'")?
            .iter()
            .map(|e| {
                Ok((
                    e.get("round").as_f64().context("checkpoint: gossip entry round")? as u64,
                    bits::f32s_from_hex(
                        e.get("theta").as_str().context("checkpoint: gossip entry theta")?,
                    )?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let next_round =
            j.get("next_round").as_f64().context("checkpoint: missing 'next_round'")? as u64;
        let per_round_syncs: Vec<usize> = j
            .get("per_round_syncs")
            .as_arr()
            .context("checkpoint: missing 'per_round_syncs'")?
            .iter()
            .map(|v| v.as_usize().context("checkpoint: non-numeric sync count"))
            .collect::<Result<_>>()?;
        ensure!(
            per_round_syncs.len() as u64 == next_round,
            "checkpoint: {} sync counts for {} completed rounds",
            per_round_syncs.len(),
            next_round
        );
        let workers = j
            .get("workers")
            .as_arr()
            .context("checkpoint: missing 'workers'")?
            .to_vec();
        ensure!(
            workers.len() == gossip.len(),
            "checkpoint: {} worker states but {} gossip entries",
            workers.len(),
            gossip.len()
        );
        // A present sync payload must carry a mode tag this build knows.
        // Decoding an unknown/corrupt tag as "central" would defeat the
        // cross-mode hard error `validate_resume` exists for.
        let sync = j.get("sync").clone();
        if sync != Json::Null {
            let mode = sync.get("mode").as_str().unwrap_or("<missing>");
            ensure!(
                mode == "gossip",
                "checkpoint: unknown sync payload mode '{mode}' (this build knows 'gossip'; \
                 central checkpoints carry no sync payload)"
            );
        }
        Ok(RunCheckpoint {
            driver,
            next_round,
            master: j.get("master").clone(),
            workers,
            gossip,
            engines: j.get("engines").clone(),
            rngs: j.get("rngs").clone(),
            sync,
            log: MetricsLog::from_json(j.get("records")).context("checkpoint: bad 'records'")?,
            per_round_syncs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunCheckpoint {
        RunCheckpoint {
            driver: DRIVER_SEQUENTIAL.into(),
            next_round: 2,
            master: Json::obj(vec![("theta", Json::str("3f800000"))]),
            workers: vec![Json::Null, Json::Null],
            gossip: vec![(1, vec![1.0, -0.5]), (0, vec![0.0, 0.0])],
            engines: Json::obj(vec![("all", Json::Null)]),
            rngs: Json::obj(vec![("order", Json::Null)]),
            sync: Json::Null,
            log: MetricsLog::default(),
            per_round_syncs: vec![2, 1],
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let cp = sample();
        let text = cp.to_json().to_string_compact();
        let back = RunCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.driver, cp.driver);
        assert_eq!(back.next_round, 2);
        assert_eq!(back.workers.len(), 2);
        assert_eq!(back.gossip, cp.gossip);
        assert_eq!(back.per_round_syncs, vec![2, 1]);
        assert_eq!(back.to_json().to_string_compact(), text, "canonical fixed point");
    }

    /// Gossip-mode checkpoints round-trip their `sync` payload and decode
    /// the right mode tag; central checkpoints stay `sync`-less on the wire
    /// (pre-gossip encoding) and decode as central.
    #[test]
    fn sync_payload_roundtrips_and_tags_the_mode() {
        use crate::config::SyncMode;
        let central = sample();
        assert_eq!(central.sync_mode(), SyncMode::Central);
        assert!(!central.to_json().to_string_compact().contains("\"sync\""));

        let mut gossip = sample();
        gossip.sync = Json::obj(vec![
            ("mode", Json::str("gossip")),
            (
                "master_slot",
                Json::obj(vec![("round", Json::num(2.0)), ("theta", Json::str("3f800000"))]),
            ),
            ("pull_cursors", Json::Arr(vec![Json::num(1.0), Json::num(2.0)])),
            ("worker_policies", Json::Arr(vec![Json::Null, Json::Null])),
        ]);
        assert_eq!(gossip.sync_mode(), SyncMode::Gossip);
        let text = gossip.to_json().to_string_compact();
        let back = RunCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.sync_mode(), SyncMode::Gossip);
        assert_eq!(back.sync, gossip.sync);
        assert_eq!(back.to_json().to_string_compact(), text, "canonical fixed point");
    }

    #[test]
    fn malformed_checkpoints_are_rejected() {
        // wrong version
        let mut j = sample().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::num(99.0));
        }
        assert!(RunCheckpoint::from_json(&j).is_err());
        // sync-count / round mismatch
        let mut cp = sample();
        cp.per_round_syncs.pop();
        assert!(RunCheckpoint::from_json(&cp.to_json()).is_err());
        // unknown driver
        let mut cp = sample();
        cp.driver = "quantum".into();
        assert!(RunCheckpoint::from_json(&cp.to_json()).is_err());
        // worker/gossip arity mismatch
        let mut cp = sample();
        cp.workers.pop();
        assert!(RunCheckpoint::from_json(&cp.to_json()).is_err());
        // unknown/corrupt sync payload modes must NOT decode as central
        for bad_sync in [
            Json::obj(vec![("mode", Json::str("gossip "))]),
            Json::obj(vec![("mode", Json::str("quantum"))]),
            Json::obj(vec![("master_slot", Json::Null)]),
        ] {
            let mut cp = sample();
            cp.sync = bad_sync;
            let err = RunCheckpoint::from_json(&cp.to_json()).unwrap_err().to_string();
            assert!(err.contains("sync payload mode"), "{err}");
        }
    }
}
