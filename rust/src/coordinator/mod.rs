//! L3 — the paper's coordination system.
//!
//! `worker` and `master` are thread-agnostic state machines implementing
//! the elastic averaging + dynamic weighting algorithm; `sim` wires them
//! into either a deterministic sequential driver or a real threaded
//! master/worker topology over mpsc channels. `failure` injects the paper's
//! communication-suppression fault model; `scenario` compiles it into a
//! replayable per-run schedule and adds straggler speeds + elastic
//! membership; `gossip` implements the worker-to-worker master estimation;
//! `simclock` adds the virtual wall-clock model the paper defers to future
//! work.

pub mod checkpoint;
pub mod evaluator;
pub mod failure;
pub mod gossip;
pub mod master;
pub mod messages;
pub mod scenario;
pub mod sim;
pub mod simclock;
pub mod worker;

pub use failure::FailureModel;
pub use scenario::{FailureSchedule, MembershipSchedule, Scenario, TraceFile};
pub use sim::{run, Role, RunResult, Setup};
