//! The master↔worker wire protocol of the threaded driver.
//!
//! Plain `std::sync::mpsc` channels: the master thread owns one receiver;
//! every worker holds a cloned sender plus its own reply channel. A real
//! deployment would put these frames on a socket — the message set is the
//! same (sync, snapshot, eval, shutdown).

use std::sync::mpsc::Sender;
use std::sync::Arc;

/// Reply to a successful elastic sync.
pub struct SyncReply {
    /// Post-elastic worker parameters (eq. 12 applied).
    pub theta_w: Vec<f32>,
    /// Post-elastic master parameters (eq. 13 applied) — becomes the
    /// worker's gossip-published master estimate.
    pub theta_m: Arc<Vec<f32>>,
    pub h1: f64,
    pub h2: f64,
}

pub enum ToMaster {
    /// Elastic sync request (paper eqs. 12-13).
    Sync {
        worker: usize,
        round: u64,
        theta_w: Vec<f32>,
        raw_score: Option<f64>,
        missed: u32,
        reply: Sender<SyncReply>,
    },
    /// Gossip sync mode: end-of-round fold. The monitor reports which
    /// workers pulled this round (with the (h1, h2) their policies chose,
    /// in worker-index order); the master absorbs each one's freshly
    /// published board replica (eq. 13) and publishes its next aggregate
    /// snapshot before replying — workers are parked between the round
    /// barriers while this runs, so the fold is a consistent cut.
    FoldRound {
        round: u64,
        /// (worker, h1, h2) per worker that pulled this round.
        folds: Vec<(usize, f64, f64)>,
        reply: Sender<()>,
    },
    /// Evaluate the current aggregated model on the test subset.
    Eval { reply: Sender<(f64, f64)> },
    /// Fetch a copy of the aggregated model.
    Snapshot { reply: Sender<Vec<f32>> },
    /// Serialize the master's checkpointable state (aggregate, stats,
    /// policy state, engine RNG) for a mid-trial checkpoint cut.
    Checkpoint { reply: Sender<crate::util::json::Json> },
    /// Drain and exit.
    Shutdown,
}

/// Per-round per-worker report to the monitor (metrics) thread.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub worker: usize,
    pub round: u64,
    /// False when the worker sat the round out entirely (elastic membership
    /// gap, or a straggler mid-compute): the monitor still receives exactly
    /// one report per worker per round — the barrier protocol depends on
    /// that arity — but counts an absent worker neither as synced nor as
    /// failed.
    pub present: bool,
    pub train_loss: f32,
    pub synced: bool,
    pub raw_score: Option<f64>,
    pub h1: Option<f64>,
    pub h2: Option<f64>,
}
