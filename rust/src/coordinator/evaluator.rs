//! Master-side evaluation: score the aggregated model on a (subsampled)
//! test set through the `eval` artifact, batching to the artifact's fixed
//! eval batch size.

use crate::data::{Dataset, IMAGE_PIXELS, NUM_CLASSES};
use crate::engine::{BatchRef, Engine};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

pub struct Evaluator {
    data: Arc<Dataset>,
    /// Fixed subset of test indices scored every eval (seeded once so the
    /// metric is comparable across rounds and methods).
    subset: Vec<usize>,
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
}

impl Evaluator {
    pub fn new(data: Arc<Dataset>, subset_size: usize, rng: &mut Rng) -> Evaluator {
        let n = data.len();
        let take = subset_size.min(n);
        let subset = rng.sample_indices(n, take);
        Evaluator { data, subset, x_buf: Vec::new(), y_buf: Vec::new() }
    }

    pub fn subset_len(&self) -> usize {
        self.subset.len()
    }

    /// (accuracy in [0,1], mean loss) of `theta` on the eval subset.
    pub fn evaluate(&mut self, engine: &mut dyn Engine, theta: &[f32]) -> Result<(f64, f64)> {
        let bs = engine.eval_batch_size();
        if bs <= 1 {
            // Closed-form engines (quadratic) score in one call.
            let (acc, loss) = engine.eval(theta, BatchRef { x: &[], y1h: &[] })?;
            return Ok((acc as f64, loss as f64));
        }
        self.x_buf.resize(bs * IMAGE_PIXELS, 0.0);
        self.y_buf.resize(bs * NUM_CLASSES, 0.0);
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut scored = 0usize;
        for chunk in self.subset.chunks(bs) {
            // Fixed-shape artifact: pad ragged final chunk by repeating its
            // first element, then count only the real rows.
            let mut idxs: Vec<usize> = chunk.to_vec();
            while idxs.len() < bs {
                idxs.push(chunk[0]);
            }
            self.data.fill_batch(&idxs, &mut self.x_buf, &mut self.y_buf);
            let (c, l) = engine.eval(theta, BatchRef { x: &self.x_buf, y1h: &self.y_buf })?;
            if chunk.len() == bs {
                correct += c as f64;
                loss_sum += l as f64;
                scored += bs;
            } else {
                // fraction attributable to the real rows (padding rows are
                // copies of row 0, so subtract their contribution exactly by
                // rescoring the chunk ratio — cheap approximation: weight by
                // real/bs; exact for accuracy since padding rows are
                // duplicates of a real row already counted once).
                let frac = chunk.len() as f64 / bs as f64;
                correct += c as f64 * frac;
                loss_sum += l as f64 * frac;
                scored += chunk.len();
            }
        }
        Ok((correct / scored as f64, loss_sum / scored as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::engine::quad::QuadraticEngine;

    #[test]
    fn quad_engine_single_call() {
        let data = Arc::new(synth::dataset(64, 0));
        let mut ev = Evaluator::new(data, 32, &mut Rng::new(1));
        let mut e = QuadraticEngine::new(8, 2, 0, 0.0, 0.0);
        let theta = e.optimum().to_vec();
        let (acc, loss) = ev.evaluate(&mut e, &theta).unwrap();
        assert!(loss < 1e-8);
        assert!((acc - 1.0).abs() < 1e-6);
    }

    #[test]
    fn subset_is_deterministic_and_bounded() {
        let data = Arc::new(synth::dataset(100, 0));
        let e1 = Evaluator::new(data.clone(), 64, &mut Rng::new(7));
        let e2 = Evaluator::new(data.clone(), 64, &mut Rng::new(7));
        assert_eq!(e1.subset, e2.subset);
        assert_eq!(e1.subset_len(), 64);
        let e3 = Evaluator::new(data, 1000, &mut Rng::new(7));
        assert_eq!(e3.subset_len(), 100); // clamped to dataset size
    }
}
