//! Failure injection.
//!
//! The paper's model: "we suppress the communication between a worker node
//! and the master node one-third of the time" — i.i.d. Bernoulli per sync
//! attempt. Extensions (burst, permanent, targeted) exercise regimes the
//! dynamic weighting must also survive; they appear in the ablation benches.
//!
//! Decisions are a pure function of (seed, worker, round) — a `FailureModel`
//! holds no mutable state, so the threaded and sequential drivers inject
//! *identical* fault schedules. At `Setup::build` the model is compiled
//! into a [`crate::coordinator::scenario::FailureSchedule`] (a materialized
//! bitmap, bit-for-bit the pure schedule): that is what turns `Burst`'s
//! O(rounds²) history re-scan into one forward pass, and what backs the
//! `trace:` replay model (recorded schedules re-injected byte-identically).

use crate::util::rng::Rng;

/// What a suppressed round MEANS for the worker (the paper says "we
/// suppress the communication ... one-third of time" without fixing this).
///
/// * `Node` (default): the node is down for the round — no local steps, no
///   gossip observation, no sync. Its parameters are FROZEN while the
///   master moves on, so its model is genuinely outdated at reconnect —
///   exactly the "outdated model ... likely to cause adverse effects"
///   scenario the paper mitigates. Reproduces the paper's phenomenon.
/// * `Comm`: only the master link is down; the worker keeps training on its
///   shard and gossiping. Ablation — under this reading the "stale" model
///   kept improving locally, staleness is largely benign, and mitigation
///   buys little (measured in EXPERIMENTS.md §Failure-semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailStyle {
    Node,
    Comm,
}

impl FailStyle {
    pub fn parse(s: &str) -> Option<FailStyle> {
        match s {
            "node" => Some(FailStyle::Node),
            "comm" => Some(FailStyle::Comm),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FailStyle::Node => "node",
            FailStyle::Comm => "comm",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum FailureModel {
    /// No failures (calibration runs).
    None,
    /// Paper model: each sync attempt suppressed with probability `p`.
    Bernoulli { p: f64 },
    /// Markov bursts: enter a failure burst with prob `p_start` per round;
    /// bursts last `mean_len` rounds in expectation (geometric).
    Burst { p_start: f64, mean_len: f64 },
    /// Workers in `workers` fail permanently from `from_round` on.
    Permanent { from_round: u64, workers: Vec<usize> },
    /// Replay a recorded schedule (`deahes record-trace`, format
    /// `deahes-trace/v1`): the identical fault sequence across policies,
    /// sync modes and drivers. Not a generative model — it compiles into a
    /// [`crate::coordinator::scenario::FailureSchedule`] at `Setup::build`
    /// (the pure [`FailureModel::suppressed`] cannot do IO).
    Trace { path: String },
}

impl FailureModel {
    pub fn parse(spec: &str) -> Option<FailureModel> {
        // grammar: "none" | "bernoulli:P" | "burst:P,L" | "permanent:R,w0+w1"
        //        | "trace:PATH"
        // P is a probability in [0,1]; L is a mean burst length >= 1.
        let (kind, rest) = match spec.split_once(':') {
            Some((k, r)) => (k, r),
            None => (spec, ""),
        };
        let prob = |s: &str| s.parse::<f64>().ok().filter(|p| (0.0..=1.0).contains(p));
        match kind {
            "none" if rest.is_empty() => Some(FailureModel::None),
            "bernoulli" => prob(rest).map(|p| FailureModel::Bernoulli { p }),
            "burst" => {
                let (p, l) = rest.split_once(',')?;
                let mean_len = l.parse::<f64>().ok().filter(|&x| x >= 1.0)?;
                Some(FailureModel::Burst { p_start: prob(p)?, mean_len })
            }
            "permanent" => {
                let (r, ws) = rest.split_once(',')?;
                let workers = ws
                    .split('+')
                    .map(|w| w.parse().ok())
                    .collect::<Option<Vec<usize>>>()?;
                Some(FailureModel::Permanent { from_round: r.parse().ok()?, workers })
            }
            "trace" if !rest.is_empty() => {
                Some(FailureModel::Trace { path: rest.to_string() })
            }
            _ => None,
        }
    }

    pub fn describe(&self) -> String {
        match self {
            FailureModel::None => "none".into(),
            FailureModel::Bernoulli { p } => format!("bernoulli(p={p})"),
            FailureModel::Burst { p_start, mean_len } => {
                format!("burst(p_start={p_start}, mean_len={mean_len})")
            }
            FailureModel::Permanent { from_round, workers } => {
                format!("permanent(from={from_round}, workers={workers:?})")
            }
            FailureModel::Trace { path } => format!("trace(path={path})"),
        }
    }

    /// Is worker `w`'s sync at `round` suppressed? Pure in (seed, w, round).
    pub fn suppressed(&self, seed: u64, w: usize, round: u64) -> bool {
        match self {
            FailureModel::None => false,
            FailureModel::Bernoulli { p } => {
                let mut r = Rng::new(seed)
                    .derive(0xFA11)
                    .derive(w as u64)
                    .derive(round);
                r.bernoulli(*p)
            }
            FailureModel::Burst { p_start, mean_len } => {
                // Scan from round 0 so burst membership is history-free
                // deterministic. Bursts end each round with prob 1/mean_len.
                let mut in_burst = false;
                for t in 0..=round {
                    let mut r = Rng::new(seed)
                        .derive(0xB557)
                        .derive(w as u64)
                        .derive(t);
                    if in_burst {
                        if r.bernoulli(1.0 / mean_len.max(1.0)) {
                            in_burst = false;
                        }
                    } else if r.bernoulli(*p_start) {
                        in_burst = true;
                    }
                }
                in_burst
            }
            FailureModel::Permanent { from_round, workers } => {
                round >= *from_round && workers.contains(&w)
            }
            FailureModel::Trace { path } => {
                // A trace has no pure generative form: decisions live in a
                // file, and this function cannot do IO without breaking its
                // purity contract. Every driver queries the compiled
                // `FailureSchedule` built at `Setup::build`, which loads
                // (and validates) the trace exactly once.
                panic!(
                    "FailureModel::Trace('{path}') has no pure suppressed(); \
                     query the compiled FailureSchedule instead"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(FailureModel::parse("none"), Some(FailureModel::None));
        assert_eq!(
            FailureModel::parse("bernoulli:0.333"),
            Some(FailureModel::Bernoulli { p: 0.333 })
        );
        assert_eq!(
            FailureModel::parse("burst:0.05,4"),
            Some(FailureModel::Burst { p_start: 0.05, mean_len: 4.0 })
        );
        assert_eq!(
            FailureModel::parse("permanent:10,1+3"),
            Some(FailureModel::Permanent { from_round: 10, workers: vec![1, 3] })
        );
        assert_eq!(FailureModel::parse("what"), None);
    }

    /// `describe_spec` is the inverse of `parse` over the whole grammar.
    #[test]
    fn whole_grammar_roundtrips() {
        let models = [
            FailureModel::None,
            FailureModel::Bernoulli { p: 0.0 },
            FailureModel::Bernoulli { p: 1.0 / 3.0 },
            FailureModel::Bernoulli { p: 1.0 },
            FailureModel::Burst { p_start: 0.15, mean_len: 1.0 },
            FailureModel::Burst { p_start: 0.05, mean_len: 6.5 },
            FailureModel::Permanent { from_round: 0, workers: vec![0] },
            FailureModel::Permanent { from_round: 10, workers: vec![0, 2, 7] },
            FailureModel::Trace { path: "runs/burst.trace.json".into() },
        ];
        for m in models {
            let spec = m.describe_spec();
            assert_eq!(FailureModel::parse(&spec), Some(m), "spec '{spec}'");
        }
    }

    #[test]
    fn malformed_specs_rejected() {
        let bad = [
            "",
            "none:extra",
            "bernoulli",
            "bernoulli:",
            "bernoulli:abc",
            "bernoulli:-0.1",
            "bernoulli:1.5",
            "burst:0.1",
            "burst:0.1,",
            "burst:,4",
            "burst:0.1,0.5",
            "burst:1.5,4",
            "burst:a,b",
            "permanent:5",
            "permanent:5,",
            "permanent:x,1",
            "permanent:5,a+b",
            "permanent:5,1+",
            "trace",
            "trace:",
            "bogus",
            "bogus:1",
        ];
        for spec in bad {
            assert_eq!(FailureModel::parse(spec), None, "'{spec}' should not parse");
        }
    }

    #[test]
    fn fail_style_roundtrips_and_rejects() {
        for style in [FailStyle::Node, FailStyle::Comm] {
            assert_eq!(FailStyle::parse(style.name()), Some(style));
        }
        for bad in ["", "Node", "COMM", "link", "node "] {
            assert_eq!(FailStyle::parse(bad), None, "'{bad}' should not parse");
        }
    }

    #[test]
    fn bernoulli_rate_approximates_p() {
        let m = FailureModel::Bernoulli { p: 1.0 / 3.0 };
        let total = 30_000u64;
        let fails = (0..total).filter(|&r| m.suppressed(7, 0, r)).count();
        let rate = fails as f64 / total as f64;
        assert!((rate - 1.0 / 3.0).abs() < 0.02, "{rate}");
    }

    #[test]
    fn decisions_deterministic_and_worker_independent() {
        let m = FailureModel::Bernoulli { p: 0.5 };
        for r in 0..50 {
            assert_eq!(m.suppressed(1, 2, r), m.suppressed(1, 2, r));
        }
        // different workers get different streams
        let a: Vec<bool> = (0..200).map(|r| m.suppressed(1, 0, r)).collect();
        let b: Vec<bool> = (0..200).map(|r| m.suppressed(1, 1, r)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn permanent_model() {
        let m = FailureModel::Permanent { from_round: 5, workers: vec![1] };
        assert!(!m.suppressed(0, 1, 4));
        assert!(m.suppressed(0, 1, 5));
        assert!(m.suppressed(0, 1, 500));
        assert!(!m.suppressed(0, 0, 500));
    }

    #[test]
    fn burst_produces_runs() {
        let m = FailureModel::Burst { p_start: 0.1, mean_len: 5.0 };
        let seq: Vec<bool> = (0..300).map(|r| m.suppressed(3, 0, r)).collect();
        let fail_rounds = seq.iter().filter(|&&b| b).count();
        assert!(fail_rounds > 0, "bursts should occur");
        // mean run length of failures should exceed 1 (bursty, not iid)
        let mut runs = Vec::new();
        let mut cur = 0usize;
        for &b in &seq {
            if b {
                cur += 1;
            } else if cur > 0 {
                runs.push(cur);
                cur = 0;
            }
        }
        if cur > 0 {
            runs.push(cur);
        }
        let mean_run = runs.iter().sum::<usize>() as f64 / runs.len().max(1) as f64;
        assert!(mean_run > 1.2, "mean burst length {mean_run}");
    }

    #[test]
    fn none_never_fails() {
        let m = FailureModel::None;
        assert!((0..100).all(|r| !m.suppressed(0, 0, r)));
    }
}
