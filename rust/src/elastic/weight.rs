//! The dynamic weight maps h1/h2 (paper §V.B) and the weighting policies
//! behind the six compared methods.
//!
//! Piece-wise linear maps from the raw score `a` to the elastic rates, for
//! a knee constant k < 0:
//!
//! ```text
//! h1(a) = 1                      a < k        (failure: full pull onto master)
//!       = 1 + (1-α)/k · (a-k)    k ≤ a ≤ 0    (linear blend)
//!       = α                      a > 0        (healthy: plain EASGD)
//!
//! h2(a) = 0                      a < k        (failure: no influence on master)
//!       = -α/k · a + α           k ≤ a ≤ 0
//!       = α                      a > 0
//! ```
//!
//! Both are continuous; h1 interpolates 1→α, h2 interpolates 0→α over [k,0].
//!
//! ## The sign convention (DESIGN.md §6, ablation 2)
//!
//! The paper states "if a worker fails, its raw score becomes NEGATIVE in
//! the next few time steps" and wires the failure branch to a<k<0. The
//! mechanism that makes this coherent is the **recovery dip**: when a
//! stale worker reconnects, its first sync pulls it toward the master with
//! α, collapsing the log-distance — diff ≈ ln(1−α) ≈ −0.105 at α=0.1,
//! which the recency weighting maps to a ≈ −0.056, just past the knee
//! k=−0.05. So the failure branch (h1→1 teleport, h2→0 no influence)
//! fires on the syncs immediately AFTER reconnection, while the recovering
//! model is still stale — one sync later than the oracle (EAHES-OM), which
//! is exactly why the paper finds OM ≥ DEAHES-O. Our measurements confirm
//! this ordering under burst outages (EXPERIMENTS.md §Detector).
//!
//! Both conventions are implemented:
//!   * `Detector::PaperSign` (default) — a used as printed (failure ⇔
//!     a < k). Validated: reproduces the paper's ordering.
//!   * `Detector::DriftSign` — a negated, so a growing distance lands in
//!     the failure branch ("detect the drift itself"). Measured to be
//!     actively harmful: healthy transients (distance growing toward its
//!     steady state) trigger h2=0 and starve the master — a feedback loop
//!     that can stall training (EXPERIMENTS.md §Detector). Kept as the
//!     cautionary ablation.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Detector {
    /// Use `a` exactly as defined in eq. (10).
    PaperSign,
    /// Use `-a`: drift (growing distance) triggers the failure branch.
    DriftSign,
}

impl Detector {
    pub fn parse(s: &str) -> Option<Detector> {
        match s {
            "paper-sign" => Some(Detector::PaperSign),
            "drift-sign" => Some(Detector::DriftSign),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Detector::PaperSign => "paper-sign",
            Detector::DriftSign => "drift-sign",
        }
    }

    /// The score actually fed to the maps under this convention.
    pub fn effective(self, a: f64) -> f64 {
        match self {
            Detector::PaperSign => a,
            Detector::DriftSign => -a,
        }
    }
}

/// h1: the pull exerted ON the worker (eq. 12).
pub fn h1(a: f64, alpha: f64, k: f64) -> f64 {
    debug_assert!(k < 0.0, "knee must be negative");
    if a < k {
        1.0
    } else if a <= 0.0 {
        1.0 + (1.0 - alpha) / k * (a - k)
    } else {
        alpha
    }
}

/// h2: the influence the worker exerts on the master (eq. 13).
pub fn h2(a: f64, alpha: f64, k: f64) -> f64 {
    debug_assert!(k < 0.0, "knee must be negative");
    if a < k {
        0.0
    } else if a <= 0.0 {
        -alpha / k * a + alpha
    } else {
        alpha
    }
}

/// Parameters of the dynamic policy.
#[derive(Clone, Copy, Debug)]
pub struct DynamicParams {
    pub alpha: f64,
    /// Knee constant k < 0.
    pub knee: f64,
    pub detector: Detector,
}

impl Default for DynamicParams {
    fn default() -> Self {
        DynamicParams { alpha: 0.1, knee: -0.05, detector: Detector::PaperSign }
    }
}

/// The weighting policy — one of the three regimes the paper compares.
///
/// **Frozen pre-refactor reference.** The live path is the open
/// [`crate::elastic::policy::SyncPolicy`] trait (the master owns a
/// `Box<dyn SyncPolicy>` built from a spec string); this closed enum is
/// retained, unchanged, as the reference implementation the equivalence
/// regression test (`tests/policy_equivalence.rs`) checks the trait
/// policies against pointwise. Do not wire it back into the coordinator.
#[derive(Clone, Copy, Debug)]
pub enum WeightPolicy {
    /// Fixed α both ways (EASGD / EAMSGD / EAHES / EAHES-O).
    Fixed { alpha: f64 },
    /// Oracle: knows the worker failed (EAHES-OM). On the first successful
    /// sync after ≥1 missed syncs: full correction (h1=1, h2=0).
    Oracle { alpha: f64 },
    /// Paper's contribution: weights from the raw score (DEAHES-O).
    Dynamic(DynamicParams),
}

impl WeightPolicy {
    /// Compute (h1, h2) for a sync.
    ///
    /// `raw_score` — the worker's a_t (None during warm-up);
    /// `missed`    — consecutive suppressed syncs before this one (oracle
    ///               knowledge; only the Oracle policy may look at it).
    pub fn weights(&self, raw_score: Option<f64>, missed: u32) -> (f64, f64) {
        match *self {
            WeightPolicy::Fixed { alpha } => (alpha, alpha),
            WeightPolicy::Oracle { alpha } => {
                if missed > 0 {
                    (1.0, 0.0)
                } else {
                    (alpha, alpha)
                }
            }
            WeightPolicy::Dynamic(p) => match raw_score {
                // Warm-up: approximate EASGD until a score exists.
                None => (p.alpha, p.alpha),
                Some(a) => {
                    let ae = p.detector.effective(a);
                    (h1(ae, p.alpha, p.knee), h2(ae, p.alpha, p.knee))
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    const A: f64 = 0.1;
    const K: f64 = -0.05;

    #[test]
    fn h1_branches() {
        assert_eq!(h1(-1.0, A, K), 1.0); // deep failure
        assert_eq!(h1(0.5, A, K), A); // healthy
        assert!((h1(K, A, K) - 1.0).abs() < 1e-12); // continuity at k
        assert!((h1(0.0, A, K) - A).abs() < 1e-12); // continuity at 0
        let mid = h1(K / 2.0, A, K);
        assert!(mid > A && mid < 1.0);
    }

    #[test]
    fn h2_branches() {
        assert_eq!(h2(-1.0, A, K), 0.0);
        assert_eq!(h2(0.5, A, K), A);
        assert!((h2(K, A, K)).abs() < 1e-12);
        assert!((h2(0.0, A, K) - A).abs() < 1e-12);
        let mid = h2(K / 2.0, A, K);
        assert!(mid > 0.0 && mid < A);
    }

    #[test]
    fn property_h_maps_bounded_and_monotone() {
        proptest::check("h1/h2 bounded + monotone", 300, |g| {
            let alpha = g.f64(0.01, 0.9);
            let k = -g.f64(1e-4, 1.0);
            let a1 = g.f64_edgy(-2.0, 2.0);
            let a2 = g.f64_edgy(-2.0, 2.0);
            let (lo, hi) = if a1 <= a2 { (a1, a2) } else { (a2, a1) };
            // bounds
            for a in [lo, hi] {
                let v1 = h1(a, alpha, k);
                let v2 = h2(a, alpha, k);
                // 1e-9 tolerance: h1(0) evaluates 1+(1-α)/k·(0-k) which can
                // round one ulp below α.
                assert!(v1 >= alpha - 1e-9 && v1 <= 1.0 + 1e-9, "h1={v1}");
                assert!(v2 >= -1e-9 && v2 <= alpha + 1e-9, "h2={v2}");
            }
            // h1 non-increasing, h2 non-decreasing in a
            assert!(h1(lo, alpha, k) >= h1(hi, alpha, k) - 1e-12);
            assert!(h2(lo, alpha, k) <= h2(hi, alpha, k) + 1e-12);
        });
    }

    #[test]
    fn fixed_policy_ignores_everything() {
        let p = WeightPolicy::Fixed { alpha: 0.1 };
        assert_eq!(p.weights(Some(-99.0), 5), (0.1, 0.1));
        assert_eq!(p.weights(None, 0), (0.1, 0.1));
    }

    #[test]
    fn oracle_policy_uses_missed() {
        let p = WeightPolicy::Oracle { alpha: 0.1 };
        assert_eq!(p.weights(None, 0), (0.1, 0.1));
        assert_eq!(p.weights(None, 3), (1.0, 0.0));
    }

    #[test]
    fn dynamic_policy_detects_drift_with_drift_sign() {
        let p = WeightPolicy::Dynamic(DynamicParams {
            alpha: 0.1,
            knee: -0.05,
            detector: Detector::DriftSign,
        });
        // strongly growing distance (a = +0.5) => failure branch
        let (h1v, h2v) = p.weights(Some(0.5), 0);
        assert_eq!((h1v, h2v), (1.0, 0.0));
        // stable/healthy (a slightly negative => healthy under drift-sign)
        let (h1v, h2v) = p.weights(Some(-0.01), 0);
        assert_eq!((h1v, h2v), (0.1, 0.1));
    }

    #[test]
    fn dynamic_policy_paper_sign_matches_printed_convention() {
        let p = WeightPolicy::Dynamic(DynamicParams {
            alpha: 0.1,
            knee: -0.05,
            detector: Detector::PaperSign,
        });
        let (h1v, h2v) = p.weights(Some(-0.5), 0); // a < k
        assert_eq!((h1v, h2v), (1.0, 0.0));
        let (h1v, h2v) = p.weights(Some(0.5), 0);
        assert_eq!((h1v, h2v), (0.1, 0.1));
    }

    #[test]
    fn dynamic_warmup_approximates_easgd() {
        let p = WeightPolicy::Dynamic(DynamicParams::default());
        assert_eq!(p.weights(None, 0), (0.1, 0.1));
    }
}
