//! `hysteresis(alpha=A,knee=K,detector=D,hold=M)` — the dynamic policy with
//! a latched failure branch.
//!
//! The paper's detector fires one sync LATE: the recovery dip that pushes
//! the raw score past the knee only appears on the sync *after*
//! reconnection (see the sign-convention discussion in
//! `elastic/weight.rs`), and a single noisy healthy score can end the
//! correction just as abruptly. This policy adds per-worker hysteresis: once
//! the failure branch triggers, the full correction (h1=1, h2=0) latches
//! for the worker's next `hold` syncs, smoothing the one-sync-late flicker
//! into a contiguous correction window. `hold=0` degenerates to `dynamic` —
//! guaranteed structurally: the untriggered/unlatched path delegates to an
//! embedded [`DynamicPolicy`], so the eqs. 12-13 dispatch lives in exactly
//! one place. Because that degenerate spelling silently behaves like a
//! different registered policy, `hold=0` is rejected at parse time (the
//! constructor still accepts it, which is what the structural-degeneration
//! test exercises).
//!
//! The first genuinely stateful policy — it is why [`SyncPolicy::weights`]
//! takes `&mut self` and carries the worker id in the context.

use super::dynamic::DynamicPolicy;
use super::spec::Params;
use super::{SyncContext, SyncPolicy, SyncWeights};
use crate::elastic::weight::DynamicParams;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct HysteresisPolicy {
    /// The underlying paper policy; serves every non-latched sync.
    dynamic: DynamicPolicy,
    /// Syncs the failure branch stays latched after triggering.
    pub hold: u32,
    /// Per-worker remaining latched syncs.
    latch: Vec<u32>,
}

impl HysteresisPolicy {
    pub fn new(params: DynamicParams, hold: u32) -> HysteresisPolicy {
        HysteresisPolicy { dynamic: DynamicPolicy::new(params), hold, latch: Vec::new() }
    }

    pub fn from_params(p: &mut Params) -> Result<HysteresisPolicy> {
        let dynamic = DynamicPolicy::from_params(p)?;
        let hold = p.u32("hold", 2)?;
        if hold == 0 {
            anyhow::bail!(
                "hold must be >= 1 (hold=0 makes the latch a no-op — that is exactly \
                 the 'dynamic' policy; spell it as such)"
            );
        }
        Ok(HysteresisPolicy { dynamic, hold, latch: Vec::new() })
    }

    fn slot(&mut self, worker: usize) -> &mut u32 {
        if self.latch.len() <= worker {
            self.latch.resize(worker + 1, 0);
        }
        &mut self.latch[worker]
    }
}

impl SyncPolicy for HysteresisPolicy {
    fn spec(&self) -> String {
        let p = &self.dynamic.params;
        format!(
            "hysteresis(alpha={},knee={},detector={},hold={})",
            p.alpha,
            p.knee,
            p.detector.name(),
            self.hold
        )
    }

    fn init(&mut self, workers: usize) {
        self.latch = vec![0; workers];
    }

    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights {
        let p = self.dynamic.params;
        let triggered = match ctx.raw_score {
            None => false,
            Some(a) => p.detector.effective(a) < p.knee,
        };
        let hold = self.hold;
        let latch = self.slot(ctx.worker);
        if triggered {
            // (Re-)arm the latch: this sync plus the next `hold` stay corrected.
            *latch = hold;
            return SyncWeights { h1: 1.0, h2: 0.0 };
        }
        if *latch > 0 {
            *latch -= 1;
            return SyncWeights { h1: 1.0, h2: 0.0 };
        }
        self.dynamic.weights(ctx)
    }

    fn healthy_h2(&self) -> f64 {
        self.dynamic.healthy_h2()
    }

    /// The latch table is the policy's only cross-sync state (the embedded
    /// dynamic policy is stateless).
    fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![(
            "latch",
            Json::Arr(self.latch.iter().map(|&l| Json::num(l as f64)).collect()),
        )])
    }

    fn restore(&mut self, state: &crate::util::json::Json) -> Result<()> {
        use anyhow::Context as _;
        let latch = state
            .get("latch")
            .as_arr()
            .with_context(|| format!("policy '{}': snapshot missing 'latch'", self.spec()))?;
        self.latch = latch
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as u32)
                    .with_context(|| format!("policy '{}': non-numeric latch entry", self.spec()))
            })
            .collect::<Result<Vec<u32>>>()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;

    fn policy(hold: u32) -> HysteresisPolicy {
        let mut p = HysteresisPolicy::new(DynamicParams::default(), hold);
        p.init(4);
        p
    }

    #[test]
    fn latch_extends_the_correction_window() {
        let mut p = policy(2);
        // trigger: deep failure score
        let w = p.weights(&test_ctx(1, Some(-0.5), 0));
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
        // two healthy-scored syncs stay latched
        for _ in 0..2 {
            let w = p.weights(&test_ctx(1, Some(0.5), 0));
            assert_eq!((w.h1, w.h2), (1.0, 0.0));
        }
        // then the dynamic map resumes
        let w = p.weights(&test_ctx(1, Some(0.5), 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn latch_is_per_worker() {
        let mut p = policy(3);
        let w = p.weights(&test_ctx(0, Some(-0.5), 0));
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
        // worker 2 is unaffected by worker 0's latch
        let w = p.weights(&test_ctx(2, Some(0.5), 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn retrigger_rearms() {
        let mut p = policy(2);
        p.weights(&test_ctx(0, Some(-0.5), 0));
        p.weights(&test_ctx(0, Some(0.5), 0)); // latch 2 -> 1
        p.weights(&test_ctx(0, Some(-0.5), 0)); // re-trigger: latch back to 2
        for _ in 0..2 {
            let w = p.weights(&test_ctx(0, Some(0.5), 0));
            assert_eq!((w.h1, w.h2), (1.0, 0.0));
        }
        let w = p.weights(&test_ctx(0, Some(0.5), 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn hold_zero_degenerates_to_dynamic() {
        let mut hys = policy(0);
        let mut dy = DynamicPolicy::new(DynamicParams::default());
        for (score, missed) in
            [(Some(-0.5), 0), (Some(0.5), 0), (Some(-0.01), 2), (None, 1)]
        {
            let a = hys.weights(&test_ctx(0, score, missed));
            let b = dy.weights(&test_ctx(0, score, missed));
            assert_eq!(a, b, "score={score:?} missed={missed}");
        }
    }

    #[test]
    fn warmup_without_latch_is_easgd() {
        let mut p = policy(2);
        let w = p.weights(&test_ctx(0, None, 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn snapshot_restores_armed_latches() {
        let mut p = policy(3);
        p.weights(&test_ctx(1, Some(-0.5), 0)); // arm worker 1 for 3 syncs
        p.weights(&test_ctx(1, Some(0.5), 0)); // consume one: 2 left
        let snap = p.snapshot();
        let mut q = policy(3);
        q.restore(&snap).unwrap();
        for _ in 0..2 {
            let w = q.weights(&test_ctx(1, Some(0.5), 0));
            assert_eq!((w.h1, w.h2), (1.0, 0.0));
        }
        let w = q.weights(&test_ctx(1, Some(0.5), 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1), "latch must expire exactly where it would have");
        assert!(q.restore(&crate::util::json::Json::Null).is_err());
    }

    #[test]
    fn grows_for_unseen_workers() {
        let mut p = HysteresisPolicy::new(DynamicParams::default(), 1);
        // no init() call: slot() must grow on demand
        let w = p.weights(&test_ctx(7, Some(-0.5), 0));
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
    }
}
