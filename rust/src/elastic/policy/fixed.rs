//! `fixed(alpha=A)` — EASGD's constant moving rate, both directions.
//!
//! The baseline every other policy degenerates to when healthy: h1 = h2 = α
//! regardless of score or miss history. Backs the EASGD / EAMSGD / EAHES /
//! EAHES-O presets.

use super::spec::Params;
use super::{check_alpha, SyncContext, SyncPolicy, SyncWeights};
use anyhow::Result;

#[derive(Clone, Copy, Debug)]
pub struct FixedPolicy {
    pub alpha: f64,
}

impl FixedPolicy {
    pub fn from_params(p: &mut Params) -> Result<FixedPolicy> {
        let alpha = check_alpha(p.f64("alpha", 0.1)?)?;
        Ok(FixedPolicy { alpha })
    }
}

impl SyncPolicy for FixedPolicy {
    fn spec(&self) -> String {
        format!("fixed(alpha={})", self.alpha)
    }

    fn weights(&mut self, _ctx: &SyncContext) -> SyncWeights {
        SyncWeights { h1: self.alpha, h2: self.alpha }
    }

    fn healthy_h2(&self) -> f64 {
        self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;

    #[test]
    fn ignores_everything() {
        let mut p = FixedPolicy { alpha: 0.1 };
        let w = p.weights(&test_ctx(0, Some(-99.0), 5));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
        let w = p.weights(&test_ctx(3, None, 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn spec_roundtrips() {
        let p = FixedPolicy { alpha: 0.25 };
        assert_eq!(p.spec(), "fixed(alpha=0.25)");
    }
}
