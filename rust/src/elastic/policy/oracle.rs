//! `oracle(alpha=A)` — knows exactly which syncs were missed (EAHES-OM).
//!
//! On the first successful sync after ≥1 suppressed ones it applies the full
//! correction (h1=1: teleport the worker onto the master; h2=0: the stale
//! model gets no influence). Otherwise plain EASGD. This is the upper bound
//! the paper's score-based detector is measured against.

use super::spec::Params;
use super::{check_alpha, SyncContext, SyncPolicy, SyncWeights};
use anyhow::Result;

#[derive(Clone, Copy, Debug)]
pub struct OraclePolicy {
    pub alpha: f64,
}

impl OraclePolicy {
    pub fn from_params(p: &mut Params) -> Result<OraclePolicy> {
        let alpha = check_alpha(p.f64("alpha", 0.1)?)?;
        Ok(OraclePolicy { alpha })
    }
}

impl SyncPolicy for OraclePolicy {
    fn spec(&self) -> String {
        format!("oracle(alpha={})", self.alpha)
    }

    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights {
        if ctx.missed > 0 {
            SyncWeights { h1: 1.0, h2: 0.0 }
        } else {
            SyncWeights { h1: self.alpha, h2: self.alpha }
        }
    }

    fn healthy_h2(&self) -> f64 {
        self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;

    #[test]
    fn corrects_exactly_after_misses() {
        let mut p = OraclePolicy { alpha: 0.1 };
        let w = p.weights(&test_ctx(0, None, 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
        let w = p.weights(&test_ctx(0, None, 3));
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
        // score is oracle-irrelevant
        let w = p.weights(&test_ctx(0, Some(-99.0), 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }
}
