//! The pluggable sync-policy layer: an open, spec-addressable family of
//! weighting strategies replacing the closed three-variant `WeightPolicy`
//! enum.
//!
//! Every elastic sync asks the master's policy for the pair (h1, h2) of
//! paper eqs. 12-13: h1 is the pull exerted ON the worker, h2 the influence
//! the worker exerts on the master. A policy is a [`SyncPolicy`] trait
//! object — it receives a structured [`SyncContext`] per sync and may keep
//! per-worker state across syncs (see `hysteresis`), which the enum never
//! could.
//!
//! Policies are addressed by a round-trippable **spec string** (grammar in
//! [`spec`]): `fixed(alpha=0.1)`, `oracle(alpha=0.1)`,
//! `dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)`,
//! `hysteresis(alpha=0.1,knee=-0.05,detector=paper-sign,hold=2)`,
//! `staleness(alpha=0.1,halflife=2)`, `delayed(alpha=0.1,staleness_cap=4)`,
//! `adaptive(alpha0=0.1,window=8)`. [`parse`] builds the policy,
//! [`SyncPolicy::spec`] prints the canonical spec back, and every canonical
//! spec survives `parse → spec() → parse` bit-exactly — that invariant is
//! what lets specs ride inside `ExperimentConfig` JSON and hence inside
//! schedule fingerprints (resume/dedup key on them).
//!
//! The paper's six method presets are thin aliases into this registry
//! (`Method::policy_spec` in `strategies.rs`); `--policy` on the CLI
//! overrides the preset, and `experiments::policy_sweep` sweeps specs as a
//! first-class axis.

pub mod adaptive;
pub mod delayed;
pub mod dynamic;
pub mod fixed;
pub mod hysteresis;
pub mod oracle;
pub mod spec;
pub mod staleness;

pub use adaptive::AdaptivePolicy;
pub use delayed::DelayedPolicy;
pub use dynamic::DynamicPolicy;
pub use fixed::FixedPolicy;
pub use hysteresis::HysteresisPolicy;
pub use oracle::OraclePolicy;
pub use spec::{Params, ParsedSpec};
pub use staleness::StalenessPolicy;

use anyhow::{bail, Context, Result};

/// Everything the master knows about one sync when it picks the weights.
#[derive(Clone, Copy, Debug)]
pub struct SyncContext {
    /// Worker id serving this sync (keys per-worker policy state).
    pub worker: usize,
    /// Communication round of the sync.
    pub round: u64,
    /// The worker's raw score a_t (eq. 10); `None` during warm-up.
    pub raw_score: Option<f64>,
    /// Consecutive suppressed syncs before this one.
    pub missed: u32,
    /// The run's elastic moving rate α. Every registered policy pins its
    /// own α in its spec; this carries the run-level default so future
    /// policies can inherit it instead (part of the stable context API).
    pub alpha: f64,
}

/// The weight pair a policy hands back (paper eqs. 12-13).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SyncWeights {
    /// Pull exerted ON the worker (1 = teleport onto the master).
    pub h1: f64,
    /// Influence the worker exerts on the master (0 = none).
    pub h2: f64,
}

/// A sync-weighting strategy. Implementations may keep state (per-worker or
/// global); the master owns the policy for the lifetime of a run.
pub trait SyncPolicy: Send {
    /// Canonical spec string; `parse(self.spec())` reconstructs the policy.
    fn spec(&self) -> String;

    /// Called once before the run with the worker count, so stateful
    /// policies can size their tables. Default: nothing to size.
    fn init(&mut self, _workers: usize) {}

    /// Choose (h1, h2) for one sync. `&mut self` because policies may
    /// update their state with every decision.
    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights;

    /// The h2 this policy serves in its healthy regime (its α). The master
    /// counts a sync as a *correction* when the served h2 falls below this
    /// — the baseline must come from the policy, not the run config, so
    /// the stat stays correct when `--policy` pins a different α than the
    /// run default.
    fn healthy_h2(&self) -> f64;

    /// Serialize the policy's mutable cross-sync state for a mid-trial
    /// checkpoint. Stateless policies (the default) return `Json::Null`;
    /// stateful ones must return something [`SyncPolicy::restore`] can
    /// rebuild so a resumed run serves bit-identical weights.
    fn snapshot(&self) -> crate::util::json::Json {
        crate::util::json::Json::Null
    }

    /// Restore state produced by [`SyncPolicy::snapshot`] on a policy built
    /// from the same spec (after `init`). The default accepts only `Null`.
    fn restore(&mut self, state: &crate::util::json::Json) -> Result<()> {
        if *state == crate::util::json::Json::Null {
            Ok(())
        } else {
            bail!("policy '{}' keeps no state, cannot restore a snapshot", self.spec())
        }
    }
}

/// One registry row: a policy name plus its spec-driven constructor.
pub struct PolicyDef {
    pub name: &'static str,
    pub summary: &'static str,
    build: fn(&mut Params) -> Result<Box<dyn SyncPolicy>>,
}

/// The policy registry. Adding a strategy = one module + one row here; the
/// CLI help, the round-trip property test and `experiments::policy_sweep`
/// all enumerate this table.
pub const REGISTRY: &[PolicyDef] = &[
    PolicyDef {
        name: "fixed",
        summary: "fixed(alpha=0.1) — constant EASGD rate both ways",
        build: |p| Ok(Box::new(FixedPolicy::from_params(p)?)),
    },
    PolicyDef {
        name: "oracle",
        summary: "oracle(alpha=0.1) — full correction on the first sync after misses",
        build: |p| Ok(Box::new(OraclePolicy::from_params(p)?)),
    },
    PolicyDef {
        name: "dynamic",
        summary: "dynamic(alpha=0.1,knee=-0.05,detector=paper-sign) — the paper's score-driven maps",
        build: |p| Ok(Box::new(DynamicPolicy::from_params(p)?)),
    },
    PolicyDef {
        name: "hysteresis",
        summary: "hysteresis(alpha=0.1,knee=-0.05,detector=paper-sign,hold=2) — dynamic with a latched failure branch",
        build: |p| Ok(Box::new(HysteresisPolicy::from_params(p)?)),
    },
    PolicyDef {
        name: "staleness",
        summary: "staleness(alpha=0.1,halflife=2) — score-free geometric decay in missed syncs",
        build: |p| Ok(Box::new(StalenessPolicy::from_params(p)?)),
    },
    PolicyDef {
        name: "delayed",
        summary: "delayed(alpha=0.1,staleness_cap=4) — DaSGD-style delayed averaging with a hard staleness guard",
        build: |p| Ok(Box::new(DelayedPolicy::from_params(p)?)),
    },
    PolicyDef {
        name: "adaptive",
        summary: "adaptive(alpha0=0.1,window=8) — per-worker rate from windowed sync-wait history",
        build: |p| Ok(Box::new(AdaptivePolicy::from_params(p)?)),
    },
];

/// Registered policy names, registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|d| d.name).collect()
}

/// One canonical all-defaults spec per registered policy (bare names parse
/// with every parameter defaulted).
pub fn default_specs() -> Vec<String> {
    REGISTRY
        .iter()
        .map(|d| parse(d.name).expect("registry default must parse").spec())
        .collect()
}

/// Build a policy from a spec string.
pub fn parse(spec_text: &str) -> Result<Box<dyn SyncPolicy>> {
    let parsed = ParsedSpec::parse(spec_text)?;
    let Some(def) = REGISTRY.iter().find(|d| d.name == parsed.name) else {
        bail!(
            "unknown policy '{}' (registered: {})",
            parsed.name,
            names().join(", ")
        );
    };
    let mut params = parsed.into_params();
    let policy = (def.build)(&mut params)
        .with_context(|| format!("bad policy spec '{spec_text}'"))?;
    params
        .finish()
        .with_context(|| format!("bad policy spec '{spec_text}'"))?;
    Ok(policy)
}

/// Normalize a spec to its canonical form (parse, then print back). Two
/// spellings of one policy — `fixed`, `fixed()`, `fixed( alpha = 0.1 )` —
/// all canonicalize to `fixed(alpha=0.1)`, so configs (and therefore
/// schedule fingerprints) never depend on user spelling.
pub fn canonical(spec_text: &str) -> Result<String> {
    Ok(parse(spec_text)?.spec())
}

/// Cheap validity check used by `ExperimentConfig::validate`.
pub fn validate(spec_text: &str) -> Result<()> {
    parse(spec_text).map(|_| ())
}

// ---------------- shared parameter validation ----------------

pub(crate) fn check_alpha(alpha: f64) -> Result<f64> {
    // Registry audit: alpha=0 is rejected as degenerate — it turns every
    // healthy sync into a no-op (h1=h2=0), so `fixed(alpha=0)` silently
    // behaves like "never sync" and `oracle`/`staleness` collapse into
    // pure-pull policies. `ExperimentConfig::validate` applies the same
    // (0,1] range to the run-level alpha, since every method preset embeds
    // it into its policy spec.
    if !(alpha > 0.0 && alpha <= 1.0) {
        bail!("alpha must be in (0,1] (alpha=0 makes every sync a no-op), got {alpha}");
    }
    Ok(alpha)
}

pub(crate) fn check_knee(knee: f64) -> Result<f64> {
    if !knee.is_finite() || knee >= 0.0 {
        bail!("knee must be negative and finite (paper: k < 0), got {knee}");
    }
    Ok(knee)
}

/// Context builder shared by the per-policy unit tests.
#[cfg(test)]
pub(crate) fn test_ctx(worker: usize, raw_score: Option<f64>, missed: u32) -> SyncContext {
    SyncContext { worker, round: 0, raw_score, missed, alpha: 0.1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn every_registered_spec_roundtrips() {
        // parse → spec() → parse: the canonical form must be a fixed point.
        for spec in default_specs() {
            let again = canonical(&spec).unwrap();
            assert_eq!(spec, again, "canonical spec must be a parse fixed point");
        }
    }

    #[test]
    fn spelling_variants_canonicalize_identically() {
        for (a, b) in [
            ("fixed", "fixed(alpha=0.1)"),
            ("fixed()", " fixed ( alpha = 0.1 ) "),
            ("dynamic", "dynamic(detector=paper-sign)"),
            ("staleness(halflife=2)", "staleness(alpha=0.1)"),
            ("hysteresis(hold=2)", "hysteresis"),
        ] {
            assert_eq!(canonical(a).unwrap(), canonical(b).unwrap(), "{a} vs {b}");
        }
    }

    #[test]
    fn unknown_policies_and_params_rejected() {
        assert!(parse("easgd").is_err(), "method names are presets, not policies");
        assert!(parse("fixed(beta=1)").is_err());
        assert!(parse("oracle(alpha=2)").is_err());
        assert!(parse("dynamic(knee=0.1)").is_err());
        assert!(parse("dynamic(detector=psychic)").is_err());
        assert!(parse("staleness(halflife=0)").is_err());
        assert!(parse("staleness(halflife=-3)").is_err());
        assert!(parse("hysteresis(hold=1.5)").is_err());
        assert!(parse("hysteresis(hold=-1)").is_err());
        assert!(parse("delayed(staleness_cap=-1)").is_err());
        assert!(parse("delayed(alpha=2)").is_err());
        assert!(parse("adaptive(window=1.5)").is_err());
        assert!(parse("adaptive(alpha0=0)").is_err());
        assert!(parse("adaptive(alpha=0.1)").is_err(), "adaptive's rate knob is alpha0");
    }

    /// Degenerate parameters that silently alias another policy are parse
    /// errors: `hold=0` makes hysteresis exactly `dynamic`, `alpha=0` makes
    /// every healthy sync a no-op.
    #[test]
    fn degenerate_params_rejected_with_clear_errors() {
        let err = parse("hysteresis(hold=0)").unwrap_err().to_string();
        assert!(err.contains("dynamic"), "should point at 'dynamic': {err}");
        for spec in [
            "fixed(alpha=0)",
            "oracle(alpha=0)",
            "dynamic(alpha=0)",
            "hysteresis(alpha=0)",
            "staleness(alpha=0)",
            "delayed(alpha=0)",
            "adaptive(alpha0=0)",
        ] {
            let err = parse(spec).unwrap_err().to_string();
            assert!(err.contains("(0,1]"), "'{spec}' must reject alpha=0: {err}");
        }
        let err = parse("delayed(staleness_cap=0)").unwrap_err().to_string();
        assert!(err.contains("staleness_cap"), "{err}");
        let err = parse("adaptive(window=0)").unwrap_err().to_string();
        assert!(err.contains("window"), "{err}");
    }

    #[test]
    fn unknown_policy_error_lists_registry() {
        let err = parse("bogus").unwrap_err().to_string();
        for name in names() {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
    }

    #[test]
    fn property_random_params_roundtrip() {
        // Any spec we can build from random in-range parameters must
        // canonicalize to a fixed point and rebuild an identical policy.
        proptest::check("policy spec roundtrip", 150, |g| {
            let alpha = g.f64(1e-6, 1.0);
            let knee = -g.f64(1e-6, 2.0);
            let hold = g.usize(1, 9);
            let halflife = g.f64(0.1, 20.0);
            let det = if g.bool() { "paper-sign" } else { "drift-sign" };
            let cap = g.usize(1, 12);
            let window = g.usize(1, 16);
            let specs = [
                format!("fixed(alpha={alpha})"),
                format!("oracle(alpha={alpha})"),
                format!("dynamic(alpha={alpha},knee={knee},detector={det})"),
                format!("hysteresis(alpha={alpha},knee={knee},detector={det},hold={hold})"),
                format!("staleness(alpha={alpha},halflife={halflife})"),
                format!("delayed(alpha={alpha},staleness_cap={cap})"),
                format!("adaptive(alpha0={alpha},window={window})"),
            ];
            for s in specs {
                let c1 = canonical(&s).unwrap_or_else(|e| panic!("'{s}': {e}"));
                let c2 = canonical(&c1).unwrap();
                assert_eq!(c1, c2, "canonicalization must be idempotent for '{s}'");
            }
        });
    }

    #[test]
    fn policies_are_boxable_and_stateful() {
        let mut p = parse("hysteresis(hold=1)").unwrap();
        p.init(2);
        let w = p.weights(&test_ctx(0, Some(-0.5), 0));
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
        let w = p.weights(&test_ctx(0, Some(0.5), 0));
        assert_eq!((w.h1, w.h2), (1.0, 0.0), "latch must persist across calls");
    }

    #[test]
    fn summaries_name_their_policy() {
        for d in REGISTRY {
            assert!(d.summary.starts_with(d.name), "{}", d.name);
        }
    }

    /// Snapshot/restore contract for every registered policy: after any
    /// sync history, a fresh policy restored from the snapshot must serve
    /// the exact same weights for the exact same future contexts.
    #[test]
    fn every_registered_policy_snapshot_roundtrips() {
        let history = [
            (0usize, Some(-0.5), 0u32),
            (1, Some(0.4), 2),
            (0, Some(0.3), 0),
            (2, None, 1),
        ];
        let future = [(0usize, Some(0.2), 0u32), (1, Some(-0.6), 0), (2, Some(0.1), 3)];
        for spec in default_specs() {
            let mut original = parse(&spec).unwrap();
            original.init(3);
            for &(w, a, m) in &history {
                original.weights(&test_ctx(w, a, m));
            }
            let snap = original.snapshot();
            // snapshots must survive the JSONL text round-trip
            let snap = crate::util::json::Json::parse(&snap.to_string_compact()).unwrap();
            let mut restored = parse(&spec).unwrap();
            restored.init(3);
            restored.restore(&snap).unwrap();
            for &(w, a, m) in &future {
                assert_eq!(
                    original.weights(&test_ctx(w, a, m)),
                    restored.weights(&test_ctx(w, a, m)),
                    "{spec}: restored policy diverged"
                );
            }
        }
    }

    #[test]
    fn stateless_policies_reject_foreign_snapshots() {
        let mut p = parse("fixed").unwrap();
        assert_eq!(p.snapshot(), crate::util::json::Json::Null);
        assert!(p.restore(&crate::util::json::Json::num(1.0)).is_err());
    }
}
