//! `delayed(alpha=A,staleness_cap=C)` — DaSGD-style delayed averaging with
//! a hard staleness guard.
//!
//! DaSGD (*Squeezing SGD Parallelization Performance in Distributed
//! Training Using Delayed Averaging*, Zhou et al. 2020) overlaps
//! computation and communication by averaging against a snapshot that is
//! one step behind. The gossip sync topology (`sync_mode: gossip`) embodies
//! exactly that delay: every pull runs against the master snapshot
//! published at the END of the previous round, never against a live
//! aggregate. This policy is the weighting companion: while the delay is
//! bounded it trusts plain EASGD rates,
//!
//! ```text
//! missed <  cap:  (h1, h2) = (α, α)      — delayed averaging as usual
//! missed >= cap:  (h1, h2) = (1, 0)      — replica too stale: teleport it
//!                                          back, give it no influence
//! ```
//!
//! where `missed` counts consecutive suppressed syncs (the observable
//! staleness a failure causes). Unlike `staleness(alpha,halflife)` — a
//! smooth geometric decay — this is the DaSGD trade-off stated sharply: a
//! bounded delay is free, an unbounded one is a failure. The policy also
//! runs unchanged in central mode (it only reads `missed`).
//!
//! `staleness_cap=0` is rejected as degenerate: every sync would be a full
//! correction and the healthy branch would never serve.

use super::spec::Params;
use super::{check_alpha, SyncContext, SyncPolicy, SyncWeights};
use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug)]
pub struct DelayedPolicy {
    pub alpha: f64,
    /// Consecutive missed syncs at which the delayed update stops being
    /// trusted (hard knee).
    pub staleness_cap: u32,
}

impl DelayedPolicy {
    pub fn from_params(p: &mut Params) -> Result<DelayedPolicy> {
        let alpha = check_alpha(p.f64("alpha", 0.1)?)?;
        let staleness_cap = p.u32("staleness_cap", 4)?;
        if staleness_cap == 0 {
            bail!(
                "staleness_cap must be >= 1 (staleness_cap=0 turns every sync into a full \
                 correction — the delayed-averaging branch never serves)"
            );
        }
        Ok(DelayedPolicy { alpha, staleness_cap })
    }
}

impl SyncPolicy for DelayedPolicy {
    fn spec(&self) -> String {
        format!("delayed(alpha={},staleness_cap={})", self.alpha, self.staleness_cap)
    }

    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights {
        if ctx.missed >= self.staleness_cap {
            SyncWeights { h1: 1.0, h2: 0.0 }
        } else {
            SyncWeights { h1: self.alpha, h2: self.alpha }
        }
    }

    fn healthy_h2(&self) -> f64 {
        self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;

    fn policy(cap: u32) -> DelayedPolicy {
        DelayedPolicy { alpha: 0.1, staleness_cap: cap }
    }

    #[test]
    fn bounded_delay_is_plain_easgd() {
        let mut p = policy(4);
        for missed in 0..4 {
            let w = p.weights(&test_ctx(0, None, missed));
            assert_eq!((w.h1, w.h2), (0.1, 0.1), "missed={missed}");
        }
    }

    #[test]
    fn cap_and_beyond_teleports() {
        let mut p = policy(4);
        for missed in [4, 5, 40] {
            let w = p.weights(&test_ctx(0, Some(0.9), missed));
            assert_eq!((w.h1, w.h2), (1.0, 0.0), "missed={missed}");
        }
    }

    #[test]
    fn score_is_ignored() {
        let mut p = policy(2);
        let a = p.weights(&test_ctx(0, Some(-5.0), 0));
        let b = p.weights(&test_ctx(0, Some(5.0), 0));
        assert_eq!(a, b);
    }

    #[test]
    fn spec_is_canonical() {
        assert_eq!(policy(4).spec(), "delayed(alpha=0.1,staleness_cap=4)");
    }
}
