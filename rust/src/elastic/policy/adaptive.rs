//! `adaptive(alpha0=A,window=W)` — per-worker elastic rate derived from the
//! sync-wait statistics the master already observes.
//!
//! ROADMAP follow-up to the policy layer: instead of reacting to the
//! *current* sync alone (`staleness`, `delayed`), adapt each worker's rate
//! to its recent *reliability*. The policy keeps, per worker, a ring of the
//! `missed` values observed at its last `window` served syncs — exactly the
//! wait history `MasterState`'s per-worker stats summarize — and derives
//!
//! ```text
//! m̄  = mean(ring)                  — average waits per served sync
//! r  = 1 / (1 + m̄)        ∈ (0,1]  — reliability factor
//! h2 = α₀ · r                       — a flaky worker's influence fades
//! h1 = 1 − (1 − α₀) · r             — and the pull back strengthens
//! ```
//!
//! A fully healthy worker (`m̄ = 0`) gets exactly (α₀, α₀) — plain EASGD;
//! a worker that keeps missing syncs slides continuously toward the oracle
//! correction (1, 0), and — unlike `staleness` — stays attenuated for a
//! full window after recovering instead of snapping back on its first
//! successful sync. The ring is the policy's cross-sync state and is
//! snapshot/restored bit-exactly for mid-trial checkpoints.
//!
//! `window=0` is rejected as degenerate: no history, nothing to adapt from.

use super::spec::Params;
use super::{check_alpha, SyncContext, SyncPolicy, SyncWeights};
use crate::util::json::Json;
use anyhow::{bail, Context as _, Result};

#[derive(Clone, Debug)]
pub struct AdaptivePolicy {
    pub alpha0: f64,
    /// Served syncs of history per worker.
    pub window: u32,
    /// Per-worker ring of the last `window` observed `missed` values.
    /// Capacity is reserved up front (window + 1), so steady-state updates
    /// never allocate — the gossip-mode alloc regression test runs this
    /// policy in the hot round loop.
    hist: Vec<Vec<u32>>,
}

impl AdaptivePolicy {
    pub fn from_params(p: &mut Params) -> Result<AdaptivePolicy> {
        let alpha0 = check_alpha(p.f64("alpha0", 0.1)?)?;
        let window = p.u32("window", 8)?;
        if window == 0 {
            bail!("window must be >= 1 (window=0 keeps no sync-wait history to adapt from)");
        }
        Ok(AdaptivePolicy { alpha0, window, hist: Vec::new() })
    }

    fn ring_capacity(&self) -> usize {
        self.window as usize + 1
    }

    fn slot(&mut self, worker: usize) -> &mut Vec<u32> {
        if self.hist.len() <= worker {
            let cap = self.ring_capacity();
            self.hist.resize_with(worker + 1, || Vec::with_capacity(cap));
        }
        &mut self.hist[worker]
    }
}

impl SyncPolicy for AdaptivePolicy {
    fn spec(&self) -> String {
        format!("adaptive(alpha0={},window={})", self.alpha0, self.window)
    }

    fn init(&mut self, workers: usize) {
        let cap = self.ring_capacity();
        self.hist = (0..workers).map(|_| Vec::with_capacity(cap)).collect();
    }

    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights {
        let alpha0 = self.alpha0;
        let window = self.window as usize;
        let ring = self.slot(ctx.worker);
        ring.push(ctx.missed);
        if ring.len() > window {
            ring.remove(0);
        }
        let mean = ring.iter().map(|&m| m as f64).sum::<f64>() / ring.len() as f64;
        let r = 1.0 / (1.0 + mean);
        SyncWeights { h1: 1.0 - (1.0 - alpha0) * r, h2: alpha0 * r }
    }

    fn healthy_h2(&self) -> f64 {
        self.alpha0
    }

    /// The per-worker rings are the policy's only cross-sync state.
    fn snapshot(&self) -> Json {
        Json::obj(vec![(
            "hist",
            Json::Arr(
                self.hist
                    .iter()
                    .map(|ring| {
                        Json::Arr(ring.iter().map(|&m| Json::num(m as f64)).collect())
                    })
                    .collect(),
            ),
        )])
    }

    fn restore(&mut self, state: &Json) -> Result<()> {
        let rings = state
            .get("hist")
            .as_arr()
            .with_context(|| format!("policy '{}': snapshot missing 'hist'", self.spec()))?;
        let cap = self.ring_capacity();
        let window = self.window as usize;
        let mut hist = Vec::with_capacity(rings.len());
        for (w, ring) in rings.iter().enumerate() {
            let entries = ring
                .as_arr()
                .with_context(|| format!("policy '{}': worker {w} ring is not an array", self.spec()))?;
            anyhow::ensure!(
                entries.len() <= window,
                "policy '{}': worker {w} ring holds {} entries, window is {}",
                self.spec(),
                entries.len(),
                window
            );
            let mut slot = Vec::with_capacity(cap);
            for v in entries {
                slot.push(
                    v.as_f64()
                        .with_context(|| {
                            format!("policy '{}': non-numeric ring entry", self.spec())
                        })? as u32,
                );
            }
            hist.push(slot);
        }
        self.hist = hist;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;
    use crate::util::proptest;

    fn policy(window: u32) -> AdaptivePolicy {
        let mut p = AdaptivePolicy { alpha0: 0.1, window, hist: Vec::new() };
        p.init(4);
        p
    }

    #[test]
    fn healthy_history_is_exactly_easgd() {
        let mut p = policy(4);
        for _ in 0..10 {
            let w = p.weights(&test_ctx(0, None, 0));
            assert_eq!((w.h1, w.h2), (0.1, 0.1));
        }
    }

    #[test]
    fn misses_attenuate_for_a_full_window() {
        let mut p = policy(4);
        // one sync after 3 misses: m̄ = 3 → r = 1/4
        let w = p.weights(&test_ctx(1, None, 3));
        assert!((w.h2 - 0.1 / 4.0).abs() < 1e-12);
        assert!((w.h1 - (1.0 - 0.9 / 4.0)).abs() < 1e-12);
        // three healthy syncs later the window still remembers the miss
        for _ in 0..3 {
            let w = p.weights(&test_ctx(1, None, 0));
            assert!(w.h2 < 0.1);
        }
        // once it slides out, full rate returns
        let w = p.weights(&test_ctx(1, None, 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn state_is_per_worker() {
        let mut p = policy(4);
        p.weights(&test_ctx(0, None, 5));
        let w = p.weights(&test_ctx(2, None, 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1), "worker 2 unaffected by worker 0's misses");
    }

    #[test]
    fn grows_for_unseen_workers() {
        let mut p = AdaptivePolicy { alpha0: 0.1, window: 2, hist: Vec::new() };
        let w = p.weights(&test_ctx(7, None, 1));
        assert!(w.h2 < 0.1);
    }

    #[test]
    fn snapshot_restores_the_rings_exactly() {
        let mut p = policy(3);
        p.weights(&test_ctx(0, None, 2));
        p.weights(&test_ctx(1, None, 0));
        p.weights(&test_ctx(0, None, 0));
        let snap = p.snapshot();
        // survive the JSONL text round-trip
        let snap = Json::parse(&snap.to_string_compact()).unwrap();
        let mut q = policy(3);
        q.restore(&snap).unwrap();
        for (w, missed) in [(0, 0), (1, 1), (2, 0), (0, 3)] {
            assert_eq!(
                p.weights(&test_ctx(w, None, missed)),
                q.weights(&test_ctx(w, None, missed)),
                "worker {w}"
            );
        }
        // oversized rings are rejected
        let bad = Json::obj(vec![(
            "hist",
            Json::Arr(vec![Json::Arr(vec![Json::num(0.0); 10])]),
        )]);
        assert!(policy(3).restore(&bad).is_err());
    }

    #[test]
    fn property_bounded_and_monotone_in_mean_misses() {
        proptest::check("adaptive bounded + monotone", 200, |g| {
            let alpha0 = g.f64(0.01, 0.9);
            let window = g.usize(1, 12) as u32;
            let mut p = AdaptivePolicy { alpha0, window, hist: Vec::new() };
            p.init(1);
            for _ in 0..20 {
                let missed = g.usize(0, 6) as u32;
                let w = p.weights(&test_ctx(0, None, missed));
                assert!(w.h1 >= alpha0 - 1e-12 && w.h1 <= 1.0 + 1e-12);
                assert!(w.h2 >= -1e-12 && w.h2 <= alpha0 + 1e-12);
                // h1 and h2 mirror each other around the reliability factor
                let r = w.h2 / alpha0;
                assert!((w.h1 - (1.0 - (1.0 - alpha0) * r)).abs() < 1e-12);
            }
        });
    }
}
