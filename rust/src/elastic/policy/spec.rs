//! The policy spec grammar: `name` or `name(key=value,key=value,...)`.
//!
//! Modeled on `FailureModel::parse`/`describe_spec`, but with named
//! parameters so every policy can grow knobs without positional ambiguity.
//! The grammar is deliberately tiny:
//!
//! ```text
//! spec   := name | name "(" params? ")"
//! name   := [a-z0-9-]+
//! params := param ("," param)*
//! param  := key "=" value          key := [a-z0-9_-]+, value := no ',' ')'
//! ```
//!
//! Whitespace around tokens is tolerated on input; the canonical form
//! (`SyncPolicy::spec`) contains none. Every registered policy's canonical
//! spec survives `parse → describe → parse` bit-exactly — floats are printed
//! with Rust's shortest round-trip `Display` (same convention as the failure
//! grammar) — which is what lets policy specs key schedule fingerprints.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A syntactically parsed spec: the policy name plus its raw parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ParsedSpec {
    pub name: String,
    params: BTreeMap<String, String>,
}

impl ParsedSpec {
    pub fn parse(spec: &str) -> Result<ParsedSpec> {
        let spec = spec.trim();
        if spec.is_empty() {
            bail!("empty policy spec");
        }
        let (name, body) = match spec.split_once('(') {
            None => (spec, None),
            Some((n, rest)) => {
                let inner = rest
                    .strip_suffix(')')
                    .with_context(|| format!("policy spec '{spec}': missing closing ')'"))?;
                (n.trim(), Some(inner))
            }
        };
        if name.is_empty() {
            bail!("policy spec '{spec}': empty policy name");
        }
        if !name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-') {
            bail!("policy spec '{spec}': name '{name}' must be lowercase [a-z0-9-]");
        }
        let mut params = BTreeMap::new();
        if let Some(body) = body {
            for part in body.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    // allow `name()` but reject dangling commas like `a(x=1,)`
                    if body.trim().is_empty() && params.is_empty() {
                        break;
                    }
                    bail!("policy spec '{spec}': empty parameter");
                }
                let (k, v) = part
                    .split_once('=')
                    .with_context(|| format!("policy spec '{spec}': parameter '{part}' is not key=value"))?;
                let (k, v) = (k.trim(), v.trim());
                if k.is_empty() || v.is_empty() {
                    bail!("policy spec '{spec}': parameter '{part}' has an empty key or value");
                }
                if params.insert(k.to_string(), v.to_string()).is_some() {
                    bail!("policy spec '{spec}': duplicate parameter '{k}'");
                }
            }
        }
        Ok(ParsedSpec { name: name.to_string(), params })
    }

    pub fn into_params(self) -> Params {
        self.into_params_named("policy")
    }

    /// [`ParsedSpec::into_params`] with a caller-chosen noun for error
    /// messages — the grammar is shared with optimizer specs
    /// (`optim::OptimSpec`), and an `--optimizer` mistake must not be
    /// reported as a "policy" error.
    pub fn into_params_named(self, noun: &'static str) -> Params {
        Params { noun, spec_name: self.name, map: self.params }
    }
}

/// Typed, consume-checked access to a spec's parameters. Every accessor
/// removes its key; [`Params::finish`] rejects whatever is left, so a typo'd
/// parameter name is a hard error rather than a silently applied default.
#[derive(Debug)]
pub struct Params {
    /// What kind of spec this is, for error messages ("policy", "optimizer").
    noun: &'static str,
    spec_name: String,
    map: BTreeMap<String, String>,
}

impl Params {
    pub fn f64(&mut self, key: &str, default: f64) -> Result<f64> {
        match self.map.remove(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!("{} '{}': {key}='{v}' is not a number", self.noun, self.spec_name)
            }),
        }
    }

    /// A genuinely optional numeric parameter: `None` when absent (no
    /// default substitution — the consumer decides what absence means).
    pub fn opt_f64(&mut self, key: &str) -> Result<Option<f64>> {
        match self.map.remove(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).with_context(|| {
                format!("{} '{}': {key}='{v}' is not a number", self.noun, self.spec_name)
            }),
        }
    }

    pub fn u32(&mut self, key: &str, default: u32) -> Result<u32> {
        match self.map.remove(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| {
                format!(
                    "{} '{}': {key}='{v}' is not a non-negative integer",
                    self.noun, self.spec_name
                )
            }),
        }
    }

    pub fn string(&mut self, key: &str, default: &str) -> Result<String> {
        Ok(self.map.remove(key).unwrap_or_else(|| default.to_string()))
    }

    /// Error on parameters no accessor consumed (unknown knobs).
    pub fn finish(self) -> Result<()> {
        if self.map.is_empty() {
            return Ok(());
        }
        let leftover: Vec<&str> = self.map.keys().map(|s| s.as_str()).collect();
        bail!(
            "{} '{}': unknown parameter(s) {}",
            self.noun,
            self.spec_name,
            leftover.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_name_parses() {
        let p = ParsedSpec::parse("fixed").unwrap();
        assert_eq!(p.name, "fixed");
        assert!(p.params.is_empty());
    }

    #[test]
    fn empty_parens_parse() {
        let p = ParsedSpec::parse("fixed()").unwrap();
        assert_eq!(p.name, "fixed");
        assert!(p.params.is_empty());
    }

    #[test]
    fn params_parse_with_whitespace() {
        let p = ParsedSpec::parse(" dynamic ( alpha = 0.1 , knee = -0.05 ) ").unwrap();
        assert_eq!(p.name, "dynamic");
        assert_eq!(p.params.get("alpha").map(String::as_str), Some("0.1"));
        assert_eq!(p.params.get("knee").map(String::as_str), Some("-0.05"));
    }

    #[test]
    fn malformed_specs_rejected() {
        for bad in [
            "",
            "   ",
            "fixed(",
            "fixed)x",
            "fixed(alpha)",
            "fixed(alpha=)",
            "fixed(=0.1)",
            "fixed(alpha=0.1,)",
            "fixed(alpha=0.1,alpha=0.2)",
            "Fixed",
            "fi xed",
            "(alpha=1)",
        ] {
            assert!(ParsedSpec::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn typed_accessors_and_leftover_detection() {
        let mut p = ParsedSpec::parse("x(a=0.5,n=3,s=paper-sign,zzz=1)").unwrap().into_params();
        assert_eq!(p.f64("a", 0.0).unwrap(), 0.5);
        assert_eq!(p.u32("n", 0).unwrap(), 3);
        assert_eq!(p.string("s", "").unwrap(), "paper-sign");
        assert_eq!(p.f64("missing", 7.5).unwrap(), 7.5);
        let err = p.finish().unwrap_err().to_string();
        assert!(err.contains("zzz"), "{err}");
    }

    #[test]
    fn bad_typed_values_error() {
        let mut p = ParsedSpec::parse("x(a=abc)").unwrap().into_params();
        assert!(p.f64("a", 0.0).is_err());
        let mut p = ParsedSpec::parse("x(n=-1)").unwrap().into_params();
        assert!(p.u32("n", 0).is_err());
    }
}
