//! `staleness(alpha=A,halflife=H)` — score-free staleness decay in the
//! spirit of the delayed-averaging SGD family (DaSGD, Zhou et al. 2020).
//!
//! No raw score, no gossip: the policy looks only at `missed`, the number of
//! consecutive suppressed syncs before this one (the master observes this
//! directly — unlike the oracle it needs no knowledge of WHY syncs were
//! missed, only that they were). The worker's influence decays geometrically
//! with staleness while the pull back onto the master strengthens in
//! mirror:
//!
//! ```text
//! d(missed) = 0.5^(missed / halflife)
//! h2 = α · d            (stale influence fades toward 0)
//! h1 = 1 − (1−α) · d    (pull strengthens toward a full teleport)
//! ```
//!
//! `missed=0` gives exactly (α, α) — plain EASGD when healthy; as missed
//! grows both limits approach the oracle correction (1, 0).

use super::spec::Params;
use super::{check_alpha, SyncContext, SyncPolicy, SyncWeights};
use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug)]
pub struct StalenessPolicy {
    pub alpha: f64,
    /// Missed syncs after which the decay factor halves.
    pub halflife: f64,
}

impl StalenessPolicy {
    pub fn from_params(p: &mut Params) -> Result<StalenessPolicy> {
        let alpha = check_alpha(p.f64("alpha", 0.1)?)?;
        let halflife = p.f64("halflife", 2.0)?;
        if !halflife.is_finite() || halflife <= 0.0 {
            bail!("policy 'staleness': halflife must be a positive finite number, got {halflife}");
        }
        Ok(StalenessPolicy { alpha, halflife })
    }
}

impl SyncPolicy for StalenessPolicy {
    fn spec(&self) -> String {
        format!("staleness(alpha={},halflife={})", self.alpha, self.halflife)
    }

    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights {
        let d = 0.5f64.powf(ctx.missed as f64 / self.halflife);
        SyncWeights { h1: 1.0 - (1.0 - self.alpha) * d, h2: self.alpha * d }
    }

    fn healthy_h2(&self) -> f64 {
        self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;
    use crate::util::proptest;

    #[test]
    fn healthy_is_exactly_easgd() {
        let mut p = StalenessPolicy { alpha: 0.1, halflife: 2.0 };
        let w = p.weights(&test_ctx(0, None, 0));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn one_halflife_halves_influence() {
        let mut p = StalenessPolicy { alpha: 0.1, halflife: 2.0 };
        let w = p.weights(&test_ctx(0, None, 2));
        assert!((w.h2 - 0.05).abs() < 1e-12);
        assert!((w.h1 - (1.0 - 0.9 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn deep_staleness_approaches_oracle_correction() {
        let mut p = StalenessPolicy { alpha: 0.1, halflife: 1.0 };
        let w = p.weights(&test_ctx(0, None, 40));
        assert!(w.h1 > 1.0 - 1e-9);
        assert!(w.h2 < 1e-9);
    }

    #[test]
    fn property_bounded_and_monotone_in_missed() {
        proptest::check("staleness bounded + monotone", 200, |g| {
            let alpha = g.f64(0.01, 0.9);
            let halflife = g.f64(0.1, 10.0);
            let mut p = StalenessPolicy { alpha, halflife };
            let m1 = g.usize(0, 50) as u32;
            let m2 = g.usize(0, 50) as u32;
            let (lo, hi) = if m1 <= m2 { (m1, m2) } else { (m2, m1) };
            let a = p.weights(&test_ctx(0, None, lo));
            let b = p.weights(&test_ctx(0, None, hi));
            for w in [a, b] {
                assert!(w.h1 >= alpha - 1e-12 && w.h1 <= 1.0 + 1e-12);
                assert!(w.h2 >= -1e-12 && w.h2 <= alpha + 1e-12);
            }
            // more staleness: stronger pull, weaker influence
            assert!(a.h1 <= b.h1 + 1e-12);
            assert!(a.h2 >= b.h2 - 1e-12);
        });
    }
}
