//! `dynamic(alpha=A,knee=K,detector=D)` — the paper's contribution
//! (DEAHES-O): piecewise-linear h1/h2 driven by the gossip raw score.
//!
//! Delegates the maps to [`crate::elastic::weight`] (eqs. 12-13) so the
//! trait path computes bit-identical weights to the pre-refactor
//! `WeightPolicy::Dynamic` enum arm — the equivalence regression test in
//! `tests/policy_equivalence.rs` pins this.

use super::spec::Params;
use super::{check_alpha, check_knee, SyncContext, SyncPolicy, SyncWeights};
use crate::elastic::weight::{h1, h2, Detector, DynamicParams};
use anyhow::{Context, Result};

#[derive(Clone, Copy, Debug)]
pub struct DynamicPolicy {
    pub params: DynamicParams,
}

impl DynamicPolicy {
    pub fn new(params: DynamicParams) -> DynamicPolicy {
        DynamicPolicy { params }
    }

    pub fn from_params(p: &mut Params) -> Result<DynamicPolicy> {
        let d = DynamicParams::default();
        let alpha = check_alpha(p.f64("alpha", d.alpha)?)?;
        let knee = check_knee(p.f64("knee", d.knee)?)?;
        let det = p.string("detector", d.detector.name())?;
        let detector = Detector::parse(&det)
            .with_context(|| format!("unknown detector '{det}' (paper-sign|drift-sign)"))?;
        Ok(DynamicPolicy { params: DynamicParams { alpha, knee, detector } })
    }
}

impl SyncPolicy for DynamicPolicy {
    fn spec(&self) -> String {
        format!(
            "dynamic(alpha={},knee={},detector={})",
            self.params.alpha,
            self.params.knee,
            self.params.detector.name()
        )
    }

    fn weights(&mut self, ctx: &SyncContext) -> SyncWeights {
        let p = &self.params;
        match ctx.raw_score {
            // Warm-up: approximate EASGD until a score exists.
            None => SyncWeights { h1: p.alpha, h2: p.alpha },
            Some(a) => {
                let ae = p.detector.effective(a);
                SyncWeights { h1: h1(ae, p.alpha, p.knee), h2: h2(ae, p.alpha, p.knee) }
            }
        }
    }

    fn healthy_h2(&self) -> f64 {
        self.params.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::policy::test_ctx;

    fn policy(detector: Detector) -> DynamicPolicy {
        DynamicPolicy::new(DynamicParams { alpha: 0.1, knee: -0.05, detector })
    }

    #[test]
    fn paper_sign_matches_printed_convention() {
        let mut p = policy(Detector::PaperSign);
        let w = p.weights(&test_ctx(0, Some(-0.5), 0)); // a < k: failure
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
        let w = p.weights(&test_ctx(0, Some(0.5), 0)); // healthy
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn drift_sign_negates() {
        let mut p = policy(Detector::DriftSign);
        let w = p.weights(&test_ctx(0, Some(0.5), 0)); // growing distance
        assert_eq!((w.h1, w.h2), (1.0, 0.0));
    }

    #[test]
    fn warmup_approximates_easgd() {
        let mut p = policy(Detector::PaperSign);
        let w = p.weights(&test_ctx(0, None, 2));
        assert_eq!((w.h1, w.h2), (0.1, 0.1));
    }

    #[test]
    fn spec_is_canonical() {
        let p = policy(Detector::PaperSign);
        assert_eq!(p.spec(), "dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)");
    }
}
