//! The paper's contribution: raw-score tracking (eq. 10) and the dynamic
//! weight maps h1/h2 (eqs. 12-13) that replace EASGD's fixed moving rate.

pub mod score;
pub mod weight;

pub use score::{geometric_weights, ScoreTracker};
pub use weight::{h1, h2, Detector, DynamicParams, WeightPolicy};
