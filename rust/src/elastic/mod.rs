//! The paper's contribution: raw-score tracking (eq. 10), the dynamic
//! weight maps h1/h2 (eqs. 12-13) that replace EASGD's fixed moving rate,
//! and the pluggable sync-policy layer (`policy`) that makes the weighting
//! strategy an open, spec-addressable API.

pub mod policy;
pub mod score;
pub mod weight;

pub use policy::{SyncContext, SyncPolicy, SyncWeights};
pub use score::{geometric_weights, ScoreTracker};
pub use weight::{h1, h2, Detector, DynamicParams, WeightPolicy};
