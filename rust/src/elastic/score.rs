//! The raw score (paper eq. 10): a recency-weighted sum of successive
//! differences of u_t = log ||theta_w - theta_m_estimate||.
//!
//! A `ScoreTracker` stores the last p+1 values of u (p differences) in a
//! ring buffer and evaluates
//!
//! ```text
//! a_t = Σ_{j=0..p-1} c_j (u_{t-j} − u_{t-j-1}),   Σ c_j = 1,
//! ```
//!
//! with c_0 (the most recent difference) the largest — "preferably, we want
//! to apply larger weights on the most recent terms".

/// Default history depth p (number of differences).
pub const DEFAULT_P: usize = 4;

/// Geometric recency weights c_j ∝ decay^j, normalised to sum 1.
pub fn geometric_weights(p: usize, decay: f64) -> Vec<f64> {
    assert!(p >= 1);
    assert!(decay > 0.0 && decay <= 1.0);
    let mut w: Vec<f64> = (0..p).map(|j| decay.powi(j as i32)).collect();
    let s: f64 = w.iter().sum();
    for x in &mut w {
        *x /= s;
    }
    w
}

#[derive(Clone, Debug)]
pub struct ScoreTracker {
    /// c_j, j=0 is the most recent difference. Must sum to 1.
    weights: Vec<f64>,
    /// Ring of the last (p+1) u values, newest last.
    history: Vec<f64>,
}

impl ScoreTracker {
    pub fn new(weights: Vec<f64>) -> ScoreTracker {
        let s: f64 = weights.iter().sum();
        assert!(
            (s - 1.0).abs() < 1e-9,
            "raw-score weights must sum to 1 (got {s})"
        );
        assert!(!weights.is_empty());
        ScoreTracker { weights, history: Vec::new() }
    }

    pub fn with_default() -> ScoreTracker {
        ScoreTracker::new(geometric_weights(DEFAULT_P, 0.5))
    }

    pub fn p(&self) -> usize {
        self.weights.len()
    }

    /// Record u_t = ln(distance). Distances of exactly zero are clamped
    /// (log would be -inf; can occur at round 0 when all replicas share the
    /// master's init).
    pub fn observe_distance(&mut self, dist: f64) {
        let u = dist.max(1e-12).ln();
        self.observe_u(u);
    }

    pub fn observe_u(&mut self, u: f64) {
        self.history.push(u);
        let cap = self.weights.len() + 1;
        if self.history.len() > cap {
            let drop = self.history.len() - cap;
            self.history.drain(..drop);
        }
    }

    /// Number of differences currently available.
    pub fn diffs_available(&self) -> usize {
        self.history.len().saturating_sub(1)
    }

    /// Raw score a_t, or None until at least one difference exists.
    ///
    /// With fewer than p differences the available ones are used with their
    /// weights renormalised — the warm-up behaviour (first few rounds)
    /// otherwise biases a toward 0 and masks early failures.
    pub fn raw_score(&self) -> Option<f64> {
        let d = self.diffs_available();
        if d == 0 {
            return None;
        }
        let used = d.min(self.weights.len());
        let wsum: f64 = self.weights[..used].iter().sum();
        let mut a = 0.0;
        let h = &self.history;
        let last = h.len() - 1;
        for j in 0..used {
            let diff = h[last - j] - h[last - j - 1];
            a += self.weights[j] * diff;
        }
        Some(a / wsum)
    }

    pub fn reset(&mut self) {
        self.history.clear();
    }

    /// The retained u-value ring (newest last) — the tracker's only mutable
    /// state, exposed for mid-trial checkpointing.
    pub fn history(&self) -> &[f64] {
        &self.history
    }

    /// Restore a ring previously read through [`ScoreTracker::history`].
    /// The weights are config-derived and therefore not part of the
    /// snapshot; only the ring length is validated.
    pub fn restore_history(&mut self, history: Vec<f64>) -> anyhow::Result<()> {
        anyhow::ensure!(
            history.len() <= self.weights.len() + 1,
            "score history of {} entries exceeds ring capacity {}",
            history.len(),
            self.weights.len() + 1
        );
        self.history = history;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn weights_sum_to_one_and_decay() {
        let w = geometric_weights(4, 0.5);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w[0] > w[1] && w[1] > w[2] && w[2] > w[3]);
        // 0.5-decay over 4: 8/15, 4/15, 2/15, 1/15
        assert!((w[0] - 8.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn no_score_without_history() {
        let t = ScoreTracker::with_default();
        assert_eq!(t.raw_score(), None);
    }

    #[test]
    fn constant_distance_scores_zero() {
        let mut t = ScoreTracker::with_default();
        for _ in 0..10 {
            t.observe_distance(3.0);
        }
        assert!(t.raw_score().unwrap().abs() < 1e-12);
    }

    #[test]
    fn growing_distance_scores_positive() {
        let mut t = ScoreTracker::with_default();
        for i in 1..=6 {
            t.observe_distance(i as f64);
        }
        assert!(t.raw_score().unwrap() > 0.0);
    }

    #[test]
    fn shrinking_distance_scores_negative() {
        let mut t = ScoreTracker::with_default();
        for i in (1..=6).rev() {
            t.observe_distance(i as f64);
        }
        assert!(t.raw_score().unwrap() < 0.0);
    }

    #[test]
    fn single_diff_equals_that_diff() {
        let mut t = ScoreTracker::with_default();
        t.observe_u(1.0);
        t.observe_u(1.5);
        assert!((t.raw_score().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn recency_weighting_dominates() {
        // long stable history then a sharp recent jump: the score must be
        // pulled strongly toward the jump.
        let mut t = ScoreTracker::with_default();
        for _ in 0..5 {
            t.observe_u(0.0);
        }
        t.observe_u(1.0); // recent diff = +1
        let a = t.raw_score().unwrap();
        assert!(a > 0.5, "{a}");
    }

    #[test]
    fn zero_distance_is_clamped() {
        let mut t = ScoreTracker::with_default();
        t.observe_distance(0.0);
        t.observe_distance(0.0);
        let a = t.raw_score().unwrap();
        assert!(a.is_finite());
        assert!(a.abs() < 1e-9);
    }

    #[test]
    fn property_score_is_convex_combination_of_diffs() {
        proptest::check("raw score within diff bounds", 200, |g| {
            let p = g.usize(1, 8);
            let mut t = ScoreTracker::new(geometric_weights(p, g.f64(0.2, 1.0)));
            let n = g.usize(2, 20);
            let mut us = Vec::new();
            for _ in 0..n {
                let u = g.f64(-5.0, 5.0);
                us.push(u);
                t.observe_u(u);
            }
            let a = t.raw_score().unwrap();
            // a is a convex combination of the last min(p, n-1) diffs
            let diffs: Vec<f64> = us.windows(2).map(|w| w[1] - w[0]).collect();
            let used = diffs.len().min(p);
            let tail = &diffs[diffs.len() - used..];
            let lo = tail.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = tail.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(a >= lo - 1e-9 && a <= hi + 1e-9, "a={a} not in [{lo},{hi}]");
        });
    }
}
