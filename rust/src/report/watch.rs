//! `deahes watch` — live trial status from the run-sink tail.
//!
//! A [`WatchState`] polls `runs.jsonl` incrementally: each [`poll`]
//! reads only the bytes appended since the last one, consumes whole
//! lines (a mid-append tail waits for the next poll), and folds them
//! into a per-trial status map with the loader's own precedence — a
//! committed record beats every checkpoint, a later checkpoint with
//! `next_round >=` the current one supersedes it, an unrestorable line
//! surfaces the trial as pending. The watcher never writes; if the file
//! shrinks under it (a `deahes compact` swapped in a rewrite), it starts
//! over from byte zero.
//!
//! [`poll`]: WatchState::poll

use crate::schedule::sink::{classify_line, SinkLineKind};
use crate::schedule::RUNS_FILE;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{Read as _, Seek as _, SeekFrom};
use std::path::{Path, PathBuf};

/// Where one trial stands, per the lines seen so far.
#[derive(Clone, Debug, PartialEq)]
pub enum TrialState {
    /// A committed record line landed. `attempts` comes from the proc
    /// supervisor's `perf` telemetry when present (retries show up here).
    Committed { attempts: Option<u64> },
    /// Latest restorable mid-trial checkpoint; `next_round` is the first
    /// round a resume would execute.
    Checkpointed { next_round: u64 },
    /// Checkpoint lines exist but none restores under this build.
    Pending,
}

/// One trial's row in the status map.
#[derive(Clone, Debug)]
pub struct TrialStatus {
    pub cell: String,
    pub label: String,
    pub seed_index: u64,
    pub state: TrialState,
}

/// Incremental tail poller over one run directory's sink.
#[derive(Debug)]
pub struct WatchState {
    path: PathBuf,
    offset: u64,
    trials: BTreeMap<String, TrialStatus>,
    /// Lines neither side of the classifier could decode (crash tails,
    /// foreign-schema records, checkpoint lines with no peekable
    /// fingerprint).
    pub undecodable: usize,
}

impl WatchState {
    pub fn new(dir: &Path) -> WatchState {
        WatchState {
            path: dir.join(RUNS_FILE),
            offset: 0,
            trials: BTreeMap::new(),
            undecodable: 0,
        }
    }

    /// Fingerprint-keyed statuses, as of the last poll.
    pub fn trials(&self) -> &BTreeMap<String, TrialStatus> {
        &self.trials
    }

    /// Ingest whatever landed since the last poll. Returns whether the
    /// status map changed.
    pub fn poll(&mut self) -> Result<bool> {
        let len = match std::fs::metadata(&self.path) {
            Ok(m) => m.len(),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => 0,
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("watch: stat {}", self.path.display()))
            }
        };
        let mut changed = false;
        if len < self.offset {
            // The file shrank under us — a compact swap or a fresh run dir.
            // Everything already ingested is stale; rescan from the top.
            changed = !self.trials.is_empty() || self.undecodable > 0;
            self.offset = 0;
            self.trials.clear();
            self.undecodable = 0;
        }
        if len == self.offset {
            return Ok(changed);
        }
        let mut f = std::fs::File::open(&self.path)
            .with_context(|| format!("watch: open {}", self.path.display()))?;
        f.seek(SeekFrom::Start(self.offset))?;
        let mut buf = Vec::with_capacity((len - self.offset) as usize);
        f.take(len - self.offset).read_to_end(&mut buf)?;
        // Consume only whole lines; an in-flight append's tail stays in the
        // file for the next poll.
        let Some(last_nl) = buf.iter().rposition(|&b| b == b'\n') else {
            return Ok(changed);
        };
        self.offset += (last_nl + 1) as u64;
        let text = String::from_utf8_lossy(&buf[..=last_nl]);
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            changed |= self.ingest(line);
        }
        Ok(changed)
    }

    fn ingest(&mut self, line: &str) -> bool {
        match classify_line(line) {
            SinkLineKind::Header => false,
            SinkLineKind::Record(r) => {
                let attempts = r
                    .perf
                    .as_ref()
                    .and_then(|p| p.get("attempts").as_f64())
                    .map(|x| x as u64);
                self.trials.insert(
                    r.fingerprint.clone(),
                    TrialStatus {
                        cell: r.cell.clone(),
                        label: r.label.clone(),
                        seed_index: r.seed_index,
                        state: TrialState::Committed { attempts },
                    },
                );
                true
            }
            SinkLineKind::Checkpoint { fingerprint: Some(fp), next_round, slot } => {
                if matches!(
                    self.trials.get(&fp),
                    Some(TrialStatus { state: TrialState::Committed { .. }, .. })
                ) {
                    return false; // a committed record is final
                }
                let (cell, label, seed_index) = match (&slot, self.trials.get(&fp)) {
                    (Some(s), _) => (s.cell.clone(), s.label.clone(), s.seed_index),
                    (None, Some(t)) => (t.cell.clone(), t.label.clone(), t.seed_index),
                    (None, None) => (String::new(), String::new(), 0),
                };
                let state = match (next_round, self.trials.get(&fp).map(|t| &t.state)) {
                    // mirror the loader: a later line supersedes on >=
                    (Some(nr), Some(TrialState::Checkpointed { next_round: old })) => {
                        if nr >= *old {
                            TrialState::Checkpointed { next_round: nr }
                        } else {
                            return false;
                        }
                    }
                    (Some(nr), _) => TrialState::Checkpointed { next_round: nr },
                    (None, Some(TrialState::Checkpointed { next_round: old })) => {
                        TrialState::Checkpointed { next_round: *old }
                    }
                    (None, _) => TrialState::Pending,
                };
                self.trials
                    .insert(fp, TrialStatus { cell, label, seed_index, state });
                true
            }
            SinkLineKind::Checkpoint { fingerprint: None, .. } | SinkLineKind::Malformed => {
                self.undecodable += 1;
                true
            }
        }
    }

    /// One status block, trials ordered by (cell, seed index).
    pub fn render(&self) -> String {
        let (mut committed, mut checkpointed, mut pending) = (0usize, 0usize, 0usize);
        for t in self.trials.values() {
            match t.state {
                TrialState::Committed { .. } => committed += 1,
                TrialState::Checkpointed { .. } => checkpointed += 1,
                TrialState::Pending => pending += 1,
            }
        }
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{} — {committed} committed, {checkpointed} mid-trial, {pending} pending, \
             {} undecodable line(s)",
            self.path.display(),
            self.undecodable
        );
        let mut rows: Vec<(&String, &TrialStatus)> = self.trials.iter().collect();
        rows.sort_by(|a, b| {
            (&a.1.cell, a.1.seed_index, a.0).cmp(&(&b.1.cell, b.1.seed_index, b.0))
        });
        for (fp, t) in rows {
            let state = match &t.state {
                TrialState::Committed { attempts: Some(n) } => {
                    format!("committed (attempts={n})")
                }
                TrialState::Committed { attempts: None } => "committed".to_string(),
                TrialState::Checkpointed { next_round } => {
                    format!("checkpointed @ round {next_round}")
                }
                TrialState::Pending => "pending (state unreadable)".to_string(),
            };
            let _ = writeln!(s, "  {:<28} seed {:<2} {fp:<18} {state}", t.cell, t.seed_index);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::checkpoint::{RunCheckpoint, DRIVER_SEQUENTIAL};
    use crate::coordinator::simclock::SimClockReport;
    use crate::metrics::MetricsLog;
    use crate::schedule::checkpoint::TrialCheckpoint;
    use crate::schedule::record::TrialRecord;
    use crate::schedule::sink::{JsonlRunSink, RunSink as _};
    use crate::util::json::Json;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("deahes-watch-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rec(fp: &str) -> TrialRecord {
        TrialRecord {
            fingerprint: fp.to_string(),
            cell: "w/cell".into(),
            label: "w".into(),
            seed_index: 0,
            config: ExperimentConfig::default(),
            log: MetricsLog::default(),
            sim: SimClockReport {
                virtual_secs: 0.0,
                master_utilization: 0.0,
                mean_sync_wait: 0.0,
                p95_style_max_wait: 0.0,
                rounds: 0,
            },
            worker_stats: vec![],
            fault_digest: None,
            perf: Some(Json::obj(vec![("attempts", Json::num(2.0))])),
        }
    }

    fn ckpt(fp: &str, next_round: u64) -> TrialCheckpoint {
        TrialCheckpoint {
            fingerprint: fp.to_string(),
            cell: "w/cell".into(),
            label: "w".into(),
            seed_index: 0,
            config: ExperimentConfig::default(),
            every: 5,
            every_secs: 0.0,
            state: RunCheckpoint {
                driver: DRIVER_SEQUENTIAL.into(),
                next_round,
                master: Json::Null,
                workers: vec![Json::Null],
                gossip: vec![(0, vec![])],
                engines: Json::Null,
                rngs: Json::Null,
                sync: Json::Null,
                log: MetricsLog::default(),
                per_round_syncs: vec![1; next_round as usize],
            },
        }
    }

    fn append_raw(dir: &Path, text: &str) {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(RUNS_FILE))
            .unwrap();
        f.write_all(text.as_bytes()).unwrap();
    }

    #[test]
    fn tracks_checkpoint_progress_then_commit() {
        let dir = tmp_dir("progress");
        let mut w = WatchState::new(&dir);
        assert!(!w.poll().unwrap(), "no sink yet");
        {
            let mut sink = JsonlRunSink::open(&dir.join(RUNS_FILE)).unwrap();
            sink.checkpoint_writer().append(&ckpt("t", 3)).unwrap();
        }
        assert!(w.poll().unwrap());
        assert_eq!(
            w.trials()["t"].state,
            TrialState::Checkpointed { next_round: 3 }
        );
        append_raw(&dir, &format!("{}\n", ckpt("t", 7).to_json().to_string_compact()));
        assert!(w.poll().unwrap());
        assert_eq!(
            w.trials()["t"].state,
            TrialState::Checkpointed { next_round: 7 }
        );
        // a partial append is invisible until its newline lands
        let rec_line = rec("t").to_json().to_string_compact();
        let (head, tail) = rec_line.split_at(rec_line.len() / 2);
        append_raw(&dir, head);
        assert!(!w.poll().unwrap(), "half a line must not change anything");
        append_raw(&dir, &format!("{tail}\n"));
        assert!(w.poll().unwrap());
        assert_eq!(
            w.trials()["t"].state,
            TrialState::Committed { attempts: Some(2) }
        );
        // later checkpoints never demote a committed trial
        append_raw(&dir, &format!("{}\n", ckpt("t", 9).to_json().to_string_compact()));
        assert!(!w.poll().unwrap());
        assert!(w.render().contains("committed (attempts=2)"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An unrestorable checkpoint surfaces the trial as pending; a file
    /// that shrinks (compact swapped in a rewrite) triggers a full rescan.
    #[test]
    fn pending_status_and_shrink_rescan() {
        let dir = tmp_dir("shrink");
        {
            let _sink = JsonlRunSink::open(&dir.join(RUNS_FILE)).unwrap();
        }
        let mut j = ckpt("orphan", 4).to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("state".into(), Json::str("opaque-garbage"));
        }
        append_raw(&dir, &format!("{}\n", j.to_string_compact()));
        let mut w = WatchState::new(&dir);
        assert!(w.poll().unwrap());
        assert_eq!(w.trials()["orphan"].state, TrialState::Pending);
        assert_eq!(w.trials()["orphan"].cell, "w/cell");

        // rewrite the file shorter: header only
        let header = std::fs::read_to_string(dir.join(RUNS_FILE))
            .unwrap()
            .lines()
            .next()
            .unwrap()
            .to_string();
        std::fs::write(dir.join(RUNS_FILE), format!("{header}\n")).unwrap();
        assert!(w.poll().unwrap(), "shrink must register as a change");
        assert!(w.trials().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
