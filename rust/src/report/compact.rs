//! `deahes compact` — the one sanctioned rewriter of a run directory.
//!
//! Every *writer* treats `runs.jsonl` as append-only (sweeps, mid-trial
//! checkpoints, the proc supervisor); compact is the offline exception.
//! Mid-trial checkpoint lines carry parameter-sized state blobs, and a
//! long crash-and-resume sequence accumulates superseded ones the loader
//! will never surface again. Compact moves those out:
//!
//!  * checkpoint lines of a trial that has **committed** are dropped —
//!    the committed record is the durable fact and always supersedes them;
//!  * checkpoint lines **superseded by a later line of the same trial**
//!    are appended verbatim to a sidecar `checkpoints.jsonl` (an audit
//!    trail; nothing reads it back);
//!  * everything else — the header, every committed record line, the one
//!    surviving checkpoint per uncommitted trial, malformed tails from
//!    interrupted appends — is carried **byte-for-byte**.
//!
//! The surviving line per uncommitted trial is chosen to be exactly the
//! line `load_with_checkpoints` would surface: the last restorable
//! checkpoint winning the loader's `next_round >= best` race, or — when
//! no line restores under this build — the last line whose *identity*
//! still decodes (the loader's scratch map is last-wins), or failing even
//! that the last line outright. Before the swap the rewritten file is
//! re-loaded and compared against the original's loader view (records,
//! checkpoints and scratch identities, all byte-compared); any difference
//! aborts with the original untouched. The swap itself is
//! sidecar-append-then-atomic-rename, so a crash in between can only
//! duplicate lines into the sidecar, never lose them.

use crate::log_info;
use crate::schedule::sink::{scan_lines, JsonlRunSink, SinkContents, SinkLine, SinkLineKind};
use crate::schedule::{RunDirLock, RUNS_FILE};
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;
use std::path::Path;

/// Sidecar file superseded checkpoint lines move to, inside the run dir.
pub const CHECKPOINTS_FILE: &str = "checkpoints.jsonl";

/// What one compaction did (or, under `--dry-run`, would do).
#[derive(Debug)]
pub struct CompactReport {
    /// Committed record lines carried byte-identical.
    pub records: usize,
    /// Checkpoint lines still loader-visible, kept in place.
    pub checkpoints_kept: usize,
    /// Superseded-but-uncommitted checkpoint lines moved to the sidecar.
    pub checkpoints_moved: usize,
    /// Checkpoint lines dropped because their trial committed.
    pub checkpoints_dropped: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
    pub dry_run: bool,
}

impl CompactReport {
    pub fn render(&self) -> String {
        format!(
            "{}{} record line(s) byte-identical; checkpoints: {} kept, {} moved to {}, \
             {} dropped (trial committed); {} -> {} bytes",
            if self.dry_run { "[dry-run] " } else { "" },
            self.records,
            self.checkpoints_kept,
            self.checkpoints_moved,
            CHECKPOINTS_FILE,
            self.checkpoints_dropped,
            self.bytes_before,
            self.bytes_after,
        )
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Disposition {
    Keep,
    Sidecar,
    Drop,
}

/// Decide per line. Pure function of the scanned lines, so the policy is
/// unit-testable without touching a filesystem.
fn plan(lines: &[SinkLine]) -> Vec<Disposition> {
    let committed: BTreeSet<&str> = lines
        .iter()
        .filter_map(|l| match &l.kind {
            SinkLineKind::Record(r) => Some(r.fingerprint.as_str()),
            _ => None,
        })
        .collect();
    let mut groups: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, l) in lines.iter().enumerate() {
        // A checkpoint line whose fingerprint cannot even be peeked is left
        // in place: with no trial to attribute it to, no supersession claim
        // can be made about it.
        if let SinkLineKind::Checkpoint { fingerprint: Some(fp), .. } = &l.kind {
            groups.entry(fp).or_default().push(i);
        }
    }
    let mut out = vec![Disposition::Keep; lines.len()];
    for (fp, idxs) in groups {
        if committed.contains(fp) {
            for &i in &idxs {
                out[i] = Disposition::Drop;
            }
            continue;
        }
        // The line the loader surfaces: last restorable line winning the
        // `next_round >= best` race; else the last identity-decodable line
        // (scratch is last-wins); else the last line, kept so the loader
        // still sees (and warns about) the undecodable trial.
        let mut winner: Option<(usize, u64)> = None;
        for &i in &idxs {
            if let SinkLineKind::Checkpoint { next_round: Some(nr), .. } = &lines[i].kind {
                if winner.map_or(true, |(_, best)| *nr >= best) {
                    winner = Some((i, *nr));
                }
            }
        }
        let keep = match winner {
            Some((i, _)) => i,
            None => *idxs
                .iter()
                .rev()
                .find(|&&i| {
                    matches!(&lines[i].kind, SinkLineKind::Checkpoint { slot: Some(_), .. })
                })
                .unwrap_or_else(|| idxs.last().expect("group is non-empty")),
        };
        for &i in &idxs {
            if i != keep {
                out[i] = Disposition::Sidecar;
            }
        }
    }
    out
}

/// Compact `dir/runs.jsonl` in place (under the run-dir lock). With
/// `dry_run` the rewrite is planned and *verified* but nothing in the run
/// dir changes.
pub fn compact_run_dir(dir: &Path, dry_run: bool) -> Result<CompactReport> {
    let _lock = RunDirLock::acquire(dir)?;
    let path = dir.join(RUNS_FILE);
    let bytes_before = std::fs::metadata(&path)
        .with_context(|| format!("compact: no {RUNS_FILE} in {}", dir.display()))?
        .len();
    let before = JsonlRunSink::load_with_checkpoints(&path)?;
    let lines = scan_lines(&path)?;
    let disp = plan(&lines);

    let mut kept = String::new();
    let mut moved: Vec<&str> = Vec::new();
    let mut report = CompactReport {
        records: 0,
        checkpoints_kept: 0,
        checkpoints_moved: 0,
        checkpoints_dropped: 0,
        bytes_before,
        bytes_after: 0,
        dry_run,
    };
    for (line, d) in lines.iter().zip(&disp) {
        let is_ckpt = matches!(line.kind, SinkLineKind::Checkpoint { .. });
        match d {
            Disposition::Keep => {
                if matches!(line.kind, SinkLineKind::Record(_)) {
                    report.records += 1;
                } else if is_ckpt {
                    report.checkpoints_kept += 1;
                }
                kept.push_str(&line.raw);
                kept.push('\n');
            }
            Disposition::Sidecar => {
                report.checkpoints_moved += 1;
                moved.push(&line.raw);
            }
            Disposition::Drop => {
                report.checkpoints_dropped += 1;
            }
        }
    }
    report.bytes_after = kept.len() as u64;

    // Rewrite to a temp file in the same directory (same filesystem, so
    // the final rename is atomic) and prove the loader sees the identical
    // world before anything irreversible happens.
    let tmp = dir.join("runs.jsonl.compact-tmp");
    std::fs::write(&tmp, &kept)
        .with_context(|| format!("compact: writing {}", tmp.display()))?;
    let verdict = JsonlRunSink::load_with_checkpoints(&tmp)
        .and_then(|after| equivalent(&before, &after));
    if let Err(e) = verdict {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.context(
            "compact: rewritten file does not load identically; original left untouched",
        ));
    }
    if dry_run {
        let _ = std::fs::remove_file(&tmp);
        return Ok(report);
    }

    // Sidecar first, fsynced, then the swap: a crash between the two steps
    // duplicates lines into the sidecar (harmless — nothing reads it back),
    // never loses them.
    if !moved.is_empty() {
        let side = dir.join(CHECKPOINTS_FILE);
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&side)
            .with_context(|| format!("compact: opening sidecar {}", side.display()))?;
        for raw in &moved {
            f.write_all(raw.as_bytes())?;
            f.write_all(b"\n")?;
        }
        f.sync_all()
            .with_context(|| format!("compact: syncing sidecar {}", side.display()))?;
    }
    std::fs::File::open(&tmp)?.sync_all()?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("compact: swapping in {}", path.display()))?;
    log_info!("compact {}: {}", dir.display(), report.render());
    Ok(report)
}

/// Byte-compare the loader's view of two run files: same committed
/// records, same surviving checkpoints, same scratch identities.
fn equivalent(before: &SinkContents, after: &SinkContents) -> Result<()> {
    same_keys("committed record", &before.records, &after.records)?;
    for (fp, b) in &before.records {
        ensure!(
            b.to_json().to_string_compact() == after.records[fp].to_json().to_string_compact(),
            "committed record {fp} changed"
        );
    }
    same_keys("mid-trial checkpoint", &before.checkpoints, &after.checkpoints)?;
    for (fp, b) in &before.checkpoints {
        ensure!(
            b.to_json().to_string_compact()
                == after.checkpoints[fp].to_json().to_string_compact(),
            "surviving checkpoint for {fp} changed"
        );
    }
    same_keys("scratch identity", &before.scratch, &after.scratch)?;
    for (fp, b) in &before.scratch {
        ensure!(
            b.to_json().to_string_compact() == after.scratch[fp].to_json().to_string_compact(),
            "scratch identity for {fp} changed"
        );
    }
    Ok(())
}

fn same_keys<V>(
    what: &str,
    before: &BTreeMap<String, V>,
    after: &BTreeMap<String, V>,
) -> Result<()> {
    let b: Vec<&String> = before.keys().collect();
    let a: Vec<&String> = after.keys().collect();
    ensure!(b == a, "{what} set changed: {b:?} -> {a:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::checkpoint::{RunCheckpoint, DRIVER_SEQUENTIAL};
    use crate::coordinator::simclock::SimClockReport;
    use crate::metrics::MetricsLog;
    use crate::schedule::checkpoint::TrialCheckpoint;
    use crate::schedule::record::TrialRecord;
    use crate::schedule::sink::RunSink as _;
    use crate::util::json::Json;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("deahes-compact-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rec(fp: &str) -> TrialRecord {
        TrialRecord {
            fingerprint: fp.to_string(),
            cell: "c".into(),
            label: "c".into(),
            seed_index: 0,
            config: ExperimentConfig::default(),
            log: MetricsLog::default(),
            sim: SimClockReport {
                virtual_secs: 0.0,
                master_utilization: 0.0,
                mean_sync_wait: 0.0,
                p95_style_max_wait: 0.0,
                rounds: 0,
            },
            worker_stats: vec![],
            fault_digest: None,
            perf: None,
        }
    }

    fn ckpt(fp: &str, next_round: u64) -> TrialCheckpoint {
        TrialCheckpoint {
            fingerprint: fp.to_string(),
            cell: "c".into(),
            label: "c".into(),
            seed_index: 0,
            config: ExperimentConfig::default(),
            every: 5,
            every_secs: 0.0,
            state: RunCheckpoint {
                driver: DRIVER_SEQUENTIAL.into(),
                next_round,
                master: Json::Null,
                workers: vec![Json::Null],
                gossip: vec![(0, vec![])],
                engines: Json::Null,
                rngs: Json::Null,
                sync: Json::Null,
                log: MetricsLog::default(),
                per_round_syncs: vec![1; next_round as usize],
            },
        }
    }

    /// Append one raw line (plus newline) to an existing run file.
    fn append_line(dir: &Path, line: &str) {
        let path = dir.join(RUNS_FILE);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str(line);
        text.push('\n');
        std::fs::write(&path, text).unwrap();
    }

    #[test]
    fn drops_committed_moves_superseded_keeps_winner_byte_identical() {
        let dir = tmp_dir("mixed");
        {
            let mut sink = JsonlRunSink::open(&dir.join(RUNS_FILE)).unwrap();
            let w = sink.checkpoint_writer();
            w.append(&ckpt("done", 3)).unwrap();
            sink.append(&rec("done")).unwrap();
            w.append(&ckpt("live", 2)).unwrap();
            w.append(&ckpt("live", 5)).unwrap();
        }
        let original = std::fs::read_to_string(dir.join(RUNS_FILE)).unwrap();
        let orig_lines: Vec<&str> = original.lines().collect();
        let before = JsonlRunSink::load_with_checkpoints(&dir.join(RUNS_FILE)).unwrap();

        let r = compact_run_dir(&dir, false).unwrap();
        assert_eq!((r.records, r.checkpoints_kept), (1, 1));
        assert_eq!((r.checkpoints_moved, r.checkpoints_dropped), (1, 1));
        assert!(r.bytes_after < r.bytes_before);

        let compacted = std::fs::read_to_string(dir.join(RUNS_FILE)).unwrap();
        // header, the committed record, the winning live checkpoint — each
        // byte-identical to its original line
        let kept: Vec<&str> = compacted.lines().collect();
        assert_eq!(kept, vec![orig_lines[0], orig_lines[2], orig_lines[4]]);
        // the superseded live checkpoint moved to the sidecar verbatim
        let side = std::fs::read_to_string(dir.join(CHECKPOINTS_FILE)).unwrap();
        assert_eq!(side.lines().collect::<Vec<_>>(), vec![orig_lines[3]]);

        let after = JsonlRunSink::load_with_checkpoints(&dir.join(RUNS_FILE)).unwrap();
        equivalent(&before, &after).unwrap();
        assert_eq!(after.checkpoints["live"].next_round(), 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A trial with only unrestorable checkpoint lines keeps the LAST
    /// identity-decodable one — the loader's scratch map is last-wins — and
    /// malformed crash tails are carried untouched.
    #[test]
    fn scratch_trials_keep_the_last_identity_decodable_line() {
        let dir = tmp_dir("scratch");
        {
            let _sink = JsonlRunSink::open(&dir.join(RUNS_FILE)).unwrap();
        }
        let garbled = |nr: u64| {
            let mut j = ckpt("orphan", nr).to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("state".into(), Json::str("opaque-garbage"));
            }
            j.to_string_compact()
        };
        append_line(&dir, &garbled(4));
        append_line(&dir, &garbled(9));
        // identity also broken: config gone, fingerprint still peekable
        let mut broken = ckpt("orphan", 11).to_json();
        if let Json::Obj(m) = &mut broken {
            m.insert("state".into(), Json::str("opaque-garbage"));
            m.remove("config");
        }
        append_line(&dir, &broken.to_string_compact());
        append_line(&dir, "{\"fingerprint\":\"half\",\"cel"); // crash tail
        let before = JsonlRunSink::load_with_checkpoints(&dir.join(RUNS_FILE)).unwrap();
        assert_eq!(before.scratch.len(), 1);

        let r = compact_run_dir(&dir, false).unwrap();
        assert_eq!((r.checkpoints_kept, r.checkpoints_moved, r.checkpoints_dropped), (1, 2, 0));

        let compacted = std::fs::read_to_string(dir.join(RUNS_FILE)).unwrap();
        assert!(compacted.contains(&garbled(9)), "last identity-decodable line survives");
        assert!(!compacted.contains(&garbled(4)));
        assert!(compacted.ends_with("{\"fingerprint\":\"half\",\"cel\n"), "crash tail kept");
        let after = JsonlRunSink::load_with_checkpoints(&dir.join(RUNS_FILE)).unwrap();
        equivalent(&before, &after).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dry_run_changes_nothing() {
        let dir = tmp_dir("dry");
        {
            let mut sink = JsonlRunSink::open(&dir.join(RUNS_FILE)).unwrap();
            let w = sink.checkpoint_writer();
            w.append(&ckpt("done", 3)).unwrap();
            sink.append(&rec("done")).unwrap();
        }
        let original = std::fs::read_to_string(dir.join(RUNS_FILE)).unwrap();
        let r = compact_run_dir(&dir, true).unwrap();
        assert!(r.dry_run);
        assert_eq!(r.checkpoints_dropped, 1);
        assert_eq!(std::fs::read_to_string(dir.join(RUNS_FILE)).unwrap(), original);
        assert!(!dir.join(CHECKPOINTS_FILE).exists());
        assert!(!dir.join("runs.jsonl.compact-tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
