//! Derived views over immutable run facts.
//!
//! The facts layer is `runs.jsonl` — an append-only JSONL sink of
//! committed trial records and mid-trial checkpoints, written under the
//! run-dir lock (see [`crate::schedule::sink`]). This module is the views
//! layer on top of it:
//!
//!  * [`aggregate`] (`deahes report`) — per-cell aggregates, policy
//!    rankings and cross-run comparisons, all *read-only* and recomputed
//!    from the facts on every invocation;
//!  * [`watch`] (`deahes watch`) — an incremental tail poller deriving
//!    live per-trial status, also read-only;
//!  * [`compact`] (`deahes compact`) — the single sanctioned rewriter:
//!    it may relocate checkpoint lines the loader would never surface
//!    again, must carry every committed record byte-for-byte, and proves
//!    load-equivalence before swapping the rewrite in.
//!
//! Nothing in this module ever invents a fact: every number a view
//! prints traces to committed record bytes, and deleting every view
//! artifact (sidecars, report JSON) loses no information a resume needs.

pub mod aggregate;
pub mod compact;
pub mod watch;

pub use aggregate::{build, gather, CellReport, FingerprintRow, PerfTotals, Report, RunReport};
pub use compact::{compact_run_dir, CompactReport, CHECKPOINTS_FILE};
pub use watch::{TrialState, TrialStatus, WatchState};
