//! `deahes report` — derived views over committed run facts.
//!
//! Everything here is computed from `runs.jsonl` via the same loader the
//! sweeps use ([`JsonlRunSink::load_with_checkpoints`]), so a report can
//! never disagree with what a resume would see. Three views:
//!
//!  * **per-cell aggregates** — mean/deviation of tail accuracy (reusing
//!    [`experiments::series_from_records`], the exact averaging the
//!    figures use), sync counts, fault digests, and the proc supervisor's
//!    `perf` telemetry summed per cell;
//!  * **policy ranking** — [`experiments::rank_policies`] over the run's
//!    cells, treating each cell as one scenario of its effective policy
//!    spec;
//!  * **cross-run comparison** — given several run dirs, trials are
//!    joined by config fingerprint (stable across backends and
//!    machines); rows flag whether the committed records are
//!    byte-identical, the determinism check `schedule`'s
//!    backend-invariance promises.

use crate::experiments::{self, ScenarioOutcome};
use crate::schedule::record::TrialRecord;
use crate::schedule::sink::{JsonlRunSink, SinkContents};
use crate::schedule::RUNS_FILE;
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::path::PathBuf;

/// Proc-supervisor telemetry summed over a cell's committed records.
#[derive(Debug, Default)]
pub struct PerfTotals {
    /// Records carrying a `perf` object (sequential/thread trials carry
    /// none, so 0 here means "not a proc run").
    pub trials: usize,
    pub attempts: u64,
    pub kills_absorbed: u64,
    pub crashes_absorbed: u64,
    pub retry_wait_secs: f64,
}

/// One sweep cell's aggregate row.
#[derive(Debug)]
pub struct CellReport {
    pub cell: String,
    /// The cell's effective sync-policy spec (canonicalized).
    pub policy: String,
    pub trials: usize,
    /// Mean of each trial's tail accuracy (last 10 eval points) — the
    /// figures' "final" metric.
    pub tail_acc_mean: f64,
    pub tail_acc_std: f64,
    pub final_train_loss: f64,
    pub syncs_ok: u64,
    pub syncs_failed: u64,
    pub virtual_secs: f64,
    /// Distinct fault digests across the cell's trials (paired schedules
    /// share one digest; an empty list means a fault-free run).
    pub fault_digests: Vec<String>,
    pub perf: PerfTotals,
}

/// Everything derived from one run directory.
#[derive(Debug)]
pub struct RunReport {
    pub dir: String,
    pub committed: usize,
    /// Uncommitted trials with a restorable mid-trial checkpoint.
    pub checkpointed: usize,
    /// Uncommitted trials whose checkpoints cannot restore (re-run from
    /// scratch on resume).
    pub scratch: usize,
    pub cells: Vec<CellReport>,
    /// Policy specs ranked by mean tail accuracy across the run's cells.
    pub policies: Vec<(String, f64)>,
}

/// One fingerprint's row in the cross-run join.
#[derive(Debug)]
pub struct FingerprintRow {
    pub fingerprint: String,
    pub cell: String,
    pub seed_index: u64,
    /// Tail accuracy per run, in input order; `None` = absent there.
    pub tail_acc: Vec<Option<f64>>,
    /// Committed in at least two runs and byte-identical in every run
    /// that has it.
    pub identical: bool,
}

/// The full `deahes report` result.
#[derive(Debug)]
pub struct Report {
    pub runs: Vec<RunReport>,
    /// Fingerprint join; populated only when two or more runs were given.
    pub comparison: Vec<FingerprintRow>,
}

/// Load each run dir through the sink loader and [`build`] the report.
pub fn gather(dirs: &[PathBuf]) -> Result<Report> {
    let mut loaded = Vec::new();
    for d in dirs {
        let path = d.join(RUNS_FILE);
        ensure!(path.exists(), "report: no {RUNS_FILE} in {}", d.display());
        loaded.push((d.display().to_string(), JsonlRunSink::load_with_checkpoints(&path)?));
    }
    Ok(build(&loaded))
}

/// Pure aggregation over already-loaded sink contents.
pub fn build(runs: &[(String, SinkContents)]) -> Report {
    let reports = runs.iter().map(|(dir, c)| build_run(dir, c)).collect();
    let comparison = if runs.len() >= 2 { compare(runs) } else { Vec::new() };
    Report { runs: reports, comparison }
}

fn build_run(dir: &str, contents: &SinkContents) -> RunReport {
    let records: Vec<TrialRecord> = contents.records.values().cloned().collect();
    let series = experiments::series_from_records(&records);
    let mut by_cell: BTreeMap<&str, Vec<&TrialRecord>> = BTreeMap::new();
    for r in &records {
        by_cell.entry(r.cell.as_str()).or_default().push(r);
    }
    let mut cells = Vec::new();
    let mut outcomes = Vec::new();
    for s in &series {
        // series_from_records labels each averaged series with its cell key
        let group = &by_cell[s.label.as_str()];
        let policy = group[0].config.effective_policy_spec();
        let (mut syncs_ok, mut syncs_failed) = (0u64, 0u64);
        let mut digests: BTreeSet<&str> = BTreeSet::new();
        let mut perf = PerfTotals::default();
        for r in group {
            for round in &r.log.records {
                syncs_ok += round.syncs_ok as u64;
                syncs_failed += round.syncs_failed as u64;
            }
            if let Some(d) = &r.fault_digest {
                digests.insert(d);
            }
            if let Some(p) = &r.perf {
                perf.trials += 1;
                perf.attempts += p.get("attempts").as_f64().unwrap_or(0.0) as u64;
                perf.kills_absorbed += p.get("kills_absorbed").as_f64().unwrap_or(0.0) as u64;
                perf.crashes_absorbed +=
                    p.get("crashes_absorbed").as_f64().unwrap_or(0.0) as u64;
                perf.retry_wait_secs += p.get("retry_wait_secs").as_f64().unwrap_or(0.0);
            }
        }
        outcomes.push(ScenarioOutcome {
            scenario: s.label.clone(),
            policy: policy.clone(),
            series: s.clone(),
        });
        cells.push(CellReport {
            cell: s.label.clone(),
            policy,
            trials: group.len(),
            tail_acc_mean: s.final_acc_mean,
            tail_acc_std: s.final_acc_std,
            final_train_loss: s.final_train_loss,
            syncs_ok,
            syncs_failed,
            virtual_secs: s.virtual_secs,
            fault_digests: digests.iter().map(|d| d.to_string()).collect(),
            perf,
        });
    }
    let policies = experiments::rank_policies(&outcomes);
    RunReport {
        dir: dir.to_string(),
        committed: contents.records.len(),
        checkpointed: contents.checkpoints.len(),
        scratch: contents.scratch.len(),
        cells,
        policies,
    }
}

fn compare(runs: &[(String, SinkContents)]) -> Vec<FingerprintRow> {
    let mut fps: BTreeSet<&String> = BTreeSet::new();
    for (_, c) in runs {
        fps.extend(c.records.keys());
    }
    let mut out = Vec::new();
    for fp in fps {
        let present: Vec<Option<&TrialRecord>> =
            runs.iter().map(|(_, c)| c.records.get(fp)).collect();
        let first = present.iter().find_map(|o| *o).expect("fp came from some run");
        let bytes: Vec<String> = present
            .iter()
            .filter_map(|o| o.map(|r| r.to_json().to_string_compact()))
            .collect();
        out.push(FingerprintRow {
            fingerprint: fp.clone(),
            cell: first.cell.clone(),
            seed_index: first.seed_index,
            tail_acc: present.iter().map(|o| o.map(|r| r.log.tail_acc(10))).collect(),
            identical: bytes.len() >= 2 && bytes.windows(2).all(|w| w[0] == w[1]),
        });
    }
    out
}

impl Report {
    pub fn to_json(&self) -> Json {
        let runs = Json::Arr(self.runs.iter().map(RunReport::to_json).collect());
        let mut fields = vec![("report", Json::str("runs")), ("runs", runs)];
        if !self.comparison.is_empty() {
            fields.push((
                "comparison",
                Json::Arr(
                    self.comparison
                        .iter()
                        .map(|row| {
                            Json::obj(vec![
                                ("fingerprint", Json::str(&row.fingerprint)),
                                ("cell", Json::str(&row.cell)),
                                ("seed_index", Json::num(row.seed_index as f64)),
                                (
                                    "tail_acc",
                                    Json::Arr(
                                        row.tail_acc
                                            .iter()
                                            .map(|a| a.map_or(Json::Null, Json::num))
                                            .collect(),
                                    ),
                                ),
                                ("identical", Json::Bool(row.identical)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for run in &self.runs {
            let _ = writeln!(
                s,
                "== {} — {} committed, {} mid-trial checkpoint(s), {} scratch ==",
                run.dir, run.committed, run.checkpointed, run.scratch
            );
            if !run.cells.is_empty() {
                let _ = writeln!(
                    s,
                    "{:<28} {:<6} {:>9} {:>8} {:>9} {:>12} {:>9}  {}",
                    "cell", "trials", "tail-acc", "±std", "loss", "syncs ok/fail", "virt-s", "policy"
                );
            }
            for c in &run.cells {
                let _ = writeln!(
                    s,
                    "{:<28} {:<6} {:>9.4} {:>8.4} {:>9.4} {:>8}/{:<3} {:>9.1}  {}",
                    c.cell,
                    c.trials,
                    c.tail_acc_mean,
                    c.tail_acc_std,
                    c.final_train_loss,
                    c.syncs_ok,
                    c.syncs_failed,
                    c.virtual_secs,
                    c.policy
                );
                if !c.fault_digests.is_empty() {
                    let _ = writeln!(s, "{:<28} faults: {}", "", c.fault_digests.join(", "));
                }
                if c.perf.trials > 0 {
                    let _ = writeln!(
                        s,
                        "{:<28} proc perf: attempts={} kills={} crashes={} retry-wait={:.1}s \
                         over {} trial(s)",
                        "",
                        c.perf.attempts,
                        c.perf.kills_absorbed,
                        c.perf.crashes_absorbed,
                        c.perf.retry_wait_secs,
                        c.perf.trials
                    );
                }
            }
            if run.policies.len() > 1 {
                let _ = writeln!(s, "policy ranking (mean tail accuracy across cells):");
                for (i, (spec, acc)) in run.policies.iter().enumerate() {
                    let _ = writeln!(s, "  {}. {spec}  {acc:.4}", i + 1);
                }
            }
        }
        if !self.comparison.is_empty() {
            let _ = writeln!(s, "== cross-run comparison (by config fingerprint) ==");
            let _ = writeln!(
                s,
                "{:<18} {:<28} {:<5} {:<10} tail-acc per run",
                "fingerprint", "cell", "seed", "identical"
            );
            for row in &self.comparison {
                let accs: Vec<String> = row
                    .tail_acc
                    .iter()
                    .map(|a| a.map_or("—".to_string(), |x| format!("{x:.4}")))
                    .collect();
                let _ = writeln!(
                    s,
                    "{:<18} {:<28} {:<5} {:<10} {}",
                    row.fingerprint,
                    row.cell,
                    row.seed_index,
                    if row.identical { "yes" } else { "NO" },
                    accs.join(" | ")
                );
            }
        }
        s
    }
}

impl RunReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dir", Json::str(&self.dir)),
            ("committed", Json::num(self.committed as f64)),
            ("checkpointed", Json::num(self.checkpointed as f64)),
            ("scratch", Json::num(self.scratch as f64)),
            (
                "cells",
                Json::Arr(self.cells.iter().map(CellReport::to_json).collect()),
            ),
            (
                "policies",
                Json::Arr(
                    self.policies
                        .iter()
                        .map(|(spec, acc)| {
                            Json::obj(vec![
                                ("policy", Json::str(spec)),
                                ("mean_tail_acc", Json::num(*acc)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl CellReport {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("cell", Json::str(&self.cell)),
            ("policy", Json::str(&self.policy)),
            ("trials", Json::num(self.trials as f64)),
            ("tail_acc_mean", Json::num(self.tail_acc_mean)),
            ("tail_acc_std", Json::num(self.tail_acc_std)),
            ("final_train_loss", Json::num(self.final_train_loss)),
            ("syncs_ok", Json::num(self.syncs_ok as f64)),
            ("syncs_failed", Json::num(self.syncs_failed as f64)),
            ("virtual_secs", Json::num(self.virtual_secs)),
            (
                "fault_digests",
                Json::Arr(self.fault_digests.iter().map(|d| Json::str(d)).collect()),
            ),
        ];
        if self.perf.trials > 0 {
            fields.push((
                "perf",
                Json::obj(vec![
                    ("trials", Json::num(self.perf.trials as f64)),
                    ("attempts", Json::num(self.perf.attempts as f64)),
                    ("kills_absorbed", Json::num(self.perf.kills_absorbed as f64)),
                    ("crashes_absorbed", Json::num(self.perf.crashes_absorbed as f64)),
                    ("retry_wait_secs", Json::num(self.perf.retry_wait_secs)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::simclock::SimClockReport;
    use crate::metrics::{MetricsLog, RoundRecord};

    fn rec(fp: &str, cell: &str, seed: u64, acc: f64, policy: &str) -> TrialRecord {
        let mut log = MetricsLog::default();
        log.push(RoundRecord {
            round: 0,
            test_acc: acc,
            test_loss: 1.0 - acc,
            train_loss: 0.5,
            syncs_ok: 3,
            syncs_failed: 1,
            mean_h1: 0.0,
            mean_h2: 0.0,
            mean_score: 0.0,
        });
        TrialRecord {
            fingerprint: fp.to_string(),
            cell: cell.to_string(),
            label: cell.to_string(),
            seed_index: seed,
            config: ExperimentConfig {
                policy: Some(policy.to_string()),
                ..ExperimentConfig::default()
            },
            log,
            sim: SimClockReport {
                virtual_secs: 10.0,
                master_utilization: 0.0,
                mean_sync_wait: 0.0,
                p95_style_max_wait: 0.0,
                rounds: 1,
            },
            worker_stats: vec![],
            fault_digest: Some("cafe1234".into()),
            perf: Some(Json::obj(vec![
                ("attempts", Json::num(2.0)),
                ("kills_absorbed", Json::num(1.0)),
                ("crashes_absorbed", Json::num(0.0)),
                ("retry_wait_secs", Json::num(0.5)),
            ])),
        }
    }

    fn contents(records: &[TrialRecord]) -> SinkContents {
        let mut c = SinkContents::default();
        for r in records {
            c.records.insert(r.fingerprint.clone(), r.clone());
        }
        c
    }

    #[test]
    fn per_cell_aggregates_and_policy_ranking() {
        let c = contents(&[
            rec("a0", "cell/a", 0, 0.9, "fixed(alpha=0.5)"),
            rec("a1", "cell/a", 1, 0.8, "fixed(alpha=0.5)"),
            rec("b0", "cell/b", 0, 0.5, "fixed(alpha=0.1)"),
        ]);
        let report = build(&[("dirA".to_string(), c)]);
        assert_eq!(report.runs.len(), 1);
        assert!(report.comparison.is_empty(), "one run has nothing to compare");
        let run = &report.runs[0];
        assert_eq!((run.committed, run.checkpointed, run.scratch), (3, 0, 0));
        assert_eq!(run.cells.len(), 2);
        let a = &run.cells[0];
        assert_eq!((a.cell.as_str(), a.trials), ("cell/a", 2));
        assert!((a.tail_acc_mean - 0.85).abs() < 1e-12);
        assert_eq!((a.syncs_ok, a.syncs_failed), (6, 2));
        assert_eq!(a.fault_digests, vec!["cafe1234".to_string()]);
        assert_eq!((a.perf.trials, a.perf.attempts, a.perf.kills_absorbed), (2, 4, 2));
        // ranking: the winning policy first, ordered by mean tail accuracy
        assert_eq!(run.policies[0].0, "fixed(alpha=0.5)");
        assert!((run.policies[0].1 - 0.85).abs() < 1e-12);
        assert_eq!(run.policies[1].0, "fixed(alpha=0.1)");
        let text = report.render_text();
        assert!(text.contains("cell/a"));
        assert!(text.contains("policy ranking"));
    }

    #[test]
    fn cross_run_comparison_joins_by_fingerprint() {
        let shared = rec("s0", "cell/s", 0, 0.9, "fixed(alpha=0.5)");
        let run_a = contents(&[
            shared.clone(),
            rec("d0", "cell/d", 0, 0.7, "fixed(alpha=0.5)"),
            rec("only_a", "cell/o", 0, 0.6, "fixed(alpha=0.5)"),
        ]);
        let run_b = contents(&[shared, rec("d0", "cell/d", 0, 0.71, "fixed(alpha=0.5)")]);
        let report = build(&[("A".to_string(), run_a), ("B".to_string(), run_b)]);
        let by_fp: BTreeMap<&str, &FingerprintRow> =
            report.comparison.iter().map(|r| (r.fingerprint.as_str(), r)).collect();
        assert!(by_fp["s0"].identical, "byte-identical in both runs");
        assert!(!by_fp["d0"].identical, "diverging accuracy must flag");
        assert_eq!(by_fp["d0"].tail_acc, vec![Some(0.7), Some(0.71)]);
        assert!(!by_fp["only_a"].identical, "a single copy is not a confirmation");
        assert_eq!(by_fp["only_a"].tail_acc, vec![Some(0.6), None]);
        // the JSON document round-trips through the repo parser
        let j = report.to_json();
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.get("report").as_str(), Some("runs"));
        assert_eq!(back.get("runs").as_arr().map(|a| a.len()), Some(2));
        assert!(report.render_text().contains("cross-run comparison"));
    }
}
