//! Shared harness for the bench drivers (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` binary with `--bench`; these drivers
//! parse a small flag set from BENCH_* environment variables so the Makefile
//! can select fast vs full reproductions:
//!
//!   BENCH_SEEDS   runs to average (default 3, paper's count; 1 = smoke)
//!   BENCH_ROUNDS  communication rounds per run (default 60)
//!   BENCH_ENGINE  xla (default) | quad  — quad benches the coordinator
//!                 algorithm itself with closed-form compute
//!   BENCH_LR      learning rate (default 0.05; paper's 0.01 needs many
//!                 more rounds on the synthetic corpus)
//!   BENCH_JOBS    trials in flight (default 1 = sequential backend)
//!   BENCH_RUN_DIR persist finished trials to <dir>/runs.jsonl
//!   BENCH_RESUME  1 = skip trials already committed in BENCH_RUN_DIR

#![allow(dead_code)] // each bench binary uses a subset of this harness
#![allow(clippy::disallowed_methods)] // bench harness times wall-clock by definition

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::schedule::ScheduleOptions;
use deahes::util::logging::{self, Level};
use std::path::PathBuf;
use std::time::Instant;

pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn base_config() -> ExperimentConfig {
    logging::init(Level::Warn);
    let engine = match std::env::var("BENCH_ENGINE").as_deref() {
        Ok("quad") => EngineKind::Quadratic { dim: 256, heterogeneity: 0.2, noise: 0.05 },
        _ => EngineKind::Xla { artifacts_dir: "artifacts".into(), native_opt: false },
    };
    ExperimentConfig {
        rounds: env_u64("BENCH_ROUNDS", 60),
        lr: env_f64("BENCH_LR", 0.05),
        eval_subset: 512,
        eval_every: 2,
        engine,
        ..ExperimentConfig::default()
    }
}

pub fn seeds() -> u64 {
    env_u64("BENCH_SEEDS", 3)
}

/// Schedule options from BENCH_JOBS / BENCH_RUN_DIR / BENCH_RESUME.
pub fn schedule_options() -> ScheduleOptions {
    let run_dir = std::env::var("BENCH_RUN_DIR")
        .ok()
        .filter(|s| !s.is_empty())
        .map(PathBuf::from);
    let resume_requested = std::env::var("BENCH_RESUME").as_deref() == Ok("1");
    if resume_requested && run_dir.is_none() {
        eprintln!("[bench] BENCH_RESUME=1 ignored: set BENCH_RUN_DIR to resume from a run sink");
    }
    ScheduleOptions {
        jobs: env_u64("BENCH_JOBS", 1).max(1) as usize,
        resume: resume_requested && run_dir.is_some(),
        run_dir,
        ..ScheduleOptions::default()
    }
}

/// Time a closure and report.
pub fn timed<T>(label: &str, f: impl FnOnce() -> anyhow::Result<T>) -> anyhow::Result<T> {
    let t0 = Instant::now();
    let out = f()?;
    println!("[bench] {label}: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(out)
}
