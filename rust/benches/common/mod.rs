//! Shared harness for the bench drivers (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` binary with `--bench`; these drivers
//! parse a small flag set from BENCH_* environment variables so the Makefile
//! can select fast vs full reproductions:
//!
//!   BENCH_SEEDS   runs to average (default 3, paper's count; 1 = smoke)
//!   BENCH_ROUNDS  communication rounds per run (default 60)
//!   BENCH_ENGINE  xla (default) | quad  — quad benches the coordinator
//!                 algorithm itself with closed-form compute
//!   BENCH_LR      learning rate (default 0.05; paper's 0.01 needs many
//!                 more rounds on the synthetic corpus)

#![allow(dead_code)] // each bench binary uses a subset of this harness

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::util::logging::{self, Level};
use std::time::Instant;

pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn base_config() -> ExperimentConfig {
    logging::init(Level::Warn);
    let engine = match std::env::var("BENCH_ENGINE").as_deref() {
        Ok("quad") => EngineKind::Quadratic { dim: 256, heterogeneity: 0.2, noise: 0.05 },
        _ => EngineKind::Xla { artifacts_dir: "artifacts".into(), native_opt: false },
    };
    ExperimentConfig {
        rounds: env_u64("BENCH_ROUNDS", 60),
        lr: env_f64("BENCH_LR", 0.05),
        eval_subset: 512,
        eval_every: 2,
        engine,
        ..ExperimentConfig::default()
    }
}

pub fn seeds() -> u64 {
    env_u64("BENCH_SEEDS", 3)
}

/// Time a closure and report.
pub fn timed<T>(label: &str, f: impl FnOnce() -> anyhow::Result<T>) -> anyhow::Result<T> {
    let t0 = Instant::now();
    let out = f()?;
    println!("[bench] {label}: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(out)
}
