//! Bench: regenerate the paper's **Fig. 4** (test accuracy) and **Fig. 5**
//! (training loss) — all six methods over the (k, τ) grid with one third of
//! worker→master syncs suppressed, averaged over seeds.
//!
//!   cargo bench --bench fig4_fig5_grid
//!   BENCH_SEEDS=1 BENCH_ROUNDS=30 BENCH_GRID=small cargo bench --bench fig4_fig5_grid
//!   BENCH_JOBS=4 BENCH_RUN_DIR=runs/grid BENCH_RESUME=1 ...   # parallel + resumable
//!
//! BENCH_GRID: full  — k∈{4,8} × τ∈{1,2,4} (the paper's grid)
//!             small — k=4 × τ∈{1,2} (CI-sized)
//!
//! Expected shape (paper §VII):
//!   EAHES-OM ≥ DEAHES-O > EAHES-O > EAHES > EAMSGD ≈ EASGD
//! and performance does not degrade as k: 4→8 or τ: 1→2→4.

mod common;

use deahes::experiments;
use deahes::metrics::ascii_chart;
use deahes::strategies::ALL_METHODS;

fn main() -> anyhow::Result<()> {
    let base = common::base_config();
    let seeds = common::seeds();
    let (workers, taus): (Vec<usize>, Vec<usize>) =
        match std::env::var("BENCH_GRID").as_deref() {
            Ok("small") => (vec![4], vec![1, 2]),
            _ => (vec![4, 8], vec![1, 2, 4]),
        };

    println!(
        "== Fig 4+5 reproduction: 6 methods × k{workers:?} × tau{taus:?}, {seeds} seed(s), {} rounds ==",
        base.rounds
    );
    let opts = common::schedule_options();
    let cells = common::timed("fig4/5 grid", || {
        experiments::fig45_grid_with(&base, &workers, &taus, &ALL_METHODS, seeds, &opts)
    })?;

    for cell in &cells {
        println!("\n===== k={} tau={} =====", cell.workers, cell.tau);
        let acc: Vec<(&str, Vec<f64>)> = cell
            .series
            .iter()
            .map(|s| (s.label.as_str(), s.test_acc.clone()))
            .collect();
        print!("{}", ascii_chart("Fig 4: test accuracy over rounds", &acc, 72, 14));
        let loss: Vec<(&str, Vec<f64>)> = cell
            .series
            .iter()
            .map(|s| (s.label.as_str(), s.train_loss.clone()))
            .collect();
        print!("{}", ascii_chart("Fig 5: training loss over rounds", &loss, 72, 14));
        for s in &cell.series {
            println!(
                "  {:<10} tail acc {:>6.2}% (±{:.2}%)  train loss {:>7.4}  virtual {:>6.2}s",
                s.label,
                100.0 * s.final_acc_mean,
                100.0 * s.final_acc_std,
                s.final_train_loss,
                s.virtual_secs
            );
        }
    }

    println!("\n== §VII summary table (tail accuracy) ==");
    print!("{}", experiments::summary_table(&cells));

    // Tuned-policy promotion: run the fault-scenario battery (burst kills,
    // a no-kill straggler, membership churn — paired schedules, so every
    // policy faces identical faults) on the k=4 slice, then promote the
    // winning policy into the grid's flagship method and compare it against
    // the method's preset weighting under the grid's own failure model.
    let mut tuning_base = base.clone();
    tuning_base.workers = 4;
    tuning_base.overlap_ratio = tuning_base.method.paper_overlap_ratio(4);
    let scenarios = experiments::FaultScenario::paper_battery(4, tuning_base.rounds);
    let faulty_scenarios = &scenarios[1..]; // skip the clean control: tune on faults
    let specs: Vec<String> = ["fixed", "dynamic", "delayed(staleness_cap=4)", "adaptive"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let battery = common::timed("scenario battery (policy tuning)", || {
        experiments::scenario_battery_with(&tuning_base, faulty_scenarios, &specs, 1, &opts)
    })?;
    println!("\n== fault-scenario battery (k=4, paired schedules) ==");
    for o in &battery {
        println!(
            "  {:<10} {:<40} tail acc {:>6.2}%",
            o.scenario,
            o.policy,
            100.0 * o.series.final_acc_mean
        );
    }
    let ranked = experiments::rank_policies(&battery);
    let (tuned, tuned_acc) = ranked.first().expect("battery produced a ranking");
    println!("tuned policy (best mean tail acc across scenarios): {tuned} ({:.2}%)", 100.0 * tuned_acc);

    let mut promoted = tuning_base.clone();
    promoted.policy = Some(tuned.clone());
    let tuned_series = common::timed("fig4/5 promoted cell", || {
        experiments::averaged_run_with(&promoted, seeds, "fig45/k=4/tau=1/tuned", &opts)
    })?;
    let preset = cells
        .iter()
        .find(|c| c.workers == 4 && c.tau == promoted.tau)
        .and_then(|c| c.series.iter().find(|s| s.label == promoted.method.name()));
    match preset {
        Some(p) => println!(
            "promoted {} + {tuned}: tail acc {:.2}% vs preset {:.2}%",
            promoted.method.name(),
            100.0 * tuned_series.final_acc_mean,
            100.0 * p.final_acc_mean
        ),
        None => println!(
            "promoted {} + {tuned}: tail acc {:.2}% (preset cell not in this grid selection)",
            promoted.method.name(),
            100.0 * tuned_series.final_acc_mean
        ),
    }

    // Qualitative ordering check per cell (shape, not absolute numbers).
    println!("\nordering check per cell: DEAHES-O vs EAHES (AdaHessian, no mitigation):");
    for cell in &cells {
        let get = |name: &str| {
            cell.series
                .iter()
                .find(|s| s.label == name)
                .map(|s| s.final_acc_mean)
                .unwrap_or(0.0)
        };
        let d = get("DEAHES-O");
        let e = get("EAHES");
        println!(
            "  k={} tau={}: DEAHES-O {:.2}% vs EAHES {:.2}%  [{}]",
            cell.workers,
            cell.tau,
            100.0 * d,
            100.0 * e,
            if d >= e { "paper ordering holds" } else { "VIOLATION" }
        );
    }
    Ok(())
}
