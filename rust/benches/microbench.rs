//! Microbenchmarks of the hot paths (criterion-style timing without
//! criterion): per-artifact PJRT latency, kernel-vs-native optimizer
//! updates, raw-score pipeline, elastic sync service rate, and the
//! coordinator's non-compute overhead per sync.
//!
//!   cargo bench --bench microbench
//!
//! The L3 perf target (DESIGN.md §Perf): coordinator overhead per sync
//! (score update + h1/h2 + buffer moves, excluding XLA execute) ≤ 5% of a
//! local training step.

// Bench targets time wall-clock by definition.
#![allow(clippy::disallowed_methods)]

mod common;

use deahes::elastic::score::{geometric_weights, ScoreTracker};
use deahes::elastic::weight::{h1, h2};
use deahes::engine::xla::{OptimImpl, XlaEngine};
use deahes::engine::{BatchRef, Engine};
use deahes::optim::native;
use deahes::runtime::Manifest;
use deahes::util::rng::Rng;
use deahes::util::stats::{l2_distance, Welford};
use std::time::Instant;

fn bench<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.min(3) {
        f();
    }
    let mut w = Welford::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        w.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "{label:<44} {:>10.4} ms ± {:>8.4} ms  ({} iters)",
        w.mean() * 1e3,
        w.std_dev() * 1e3,
        iters
    );
    w.mean()
}

fn main() -> anyhow::Result<()> {
    deahes::util::logging::init(deahes::util::logging::Level::Warn);
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let n = manifest.param_count;
    let mut rng = Rng::new(0);
    let theta = manifest.init_theta(0);
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.01)).collect();
    let d: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.5, 0.1).abs()).collect();
    let bt = manifest.batch_train;
    let x = vec![0.1f32; bt * 28 * 28];
    let mut y = vec![0.0f32; bt * 10];
    for r in 0..bt {
        y[r * 10] = 1.0;
    }
    let z = rng.rademacher(n);

    println!("== L1/L2 artifact latency (PJRT, P={n}, batch={bt}) ==");
    let mut engine = XlaEngine::new(&manifest, OptimImpl::Kernels)?;
    let mut gbuf = vec![0.0f32; n];
    let mut dbuf = vec![0.0f32; n];
    let t_grad = bench("grad (fwd+bwd)", 30, || {
        engine.grad(&theta, BatchRef { x: &x, y1h: &y }, &mut gbuf).unwrap();
    });
    let t_gh = bench("grad_hess (fwd+bwd+hvp, spatial avg)", 30, || {
        engine
            .grad_hess(&theta, BatchRef { x: &x, y1h: &y }, &z, &mut gbuf, &mut dbuf)
            .unwrap();
    });
    println!(
        "   second-order overhead: grad_hess/grad = {:.2}x (AdaHessian paper: ~2x)",
        t_gh / t_grad
    );

    println!("\n== optimizer update: L1 pallas kernel vs native rust ==");
    let mut th = theta.clone();
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let mut t = 0u64;
    let kernel_ada = bench("adahessian update (pallas kernel)", 50, || {
        t += 1;
        engine
            .adahessian(&mut th, &g, &d, &mut m, &mut v, t, 0.01)
            .unwrap();
    });
    let mut th2 = theta.clone();
    let (mut m2, mut v2) = (vec![0.0f32; n], vec![0.0f32; n]);
    let mut t2 = 0u64;
    let native_ada = bench("adahessian update (native rust)", 50, || {
        t2 += 1;
        native::adahessian_step(&mut th2, &g, &d, &mut m2, &mut v2, t2, 0.01, 0.9, 0.999, 1e-8);
    });
    println!(
        "   PJRT dispatch overhead at P={n}: {:.3} ms ({:.1}x native)",
        (kernel_ada - native_ada) * 1e3,
        kernel_ada / native_ada.max(1e-12)
    );

    println!("\n== elastic sync service (master hot path) ==");
    let mut tw = theta.clone();
    let mut tm = theta.clone();
    let t_elastic = bench("elastic pair update (pallas kernel)", 50, || {
        engine.elastic(&mut tw, &mut tm, 0.1, 0.1).unwrap();
    });
    println!(
        "   master service rate: {:.0} syncs/s -> supports ~{:.0} workers at tau=1 per grad step",
        1.0 / t_elastic,
        t_grad / t_elastic
    );

    println!("\n== L3 coordinator overhead per sync (no XLA) ==");
    let weights = geometric_weights(4, 0.5);
    let mut tracker = ScoreTracker::new(weights);
    let est = theta.clone();
    let t_coord = bench("score: l2 distance + ring + raw score + h1/h2", 200, || {
        let dist = l2_distance(&theta, &est);
        tracker.observe_distance(dist);
        let a = tracker.raw_score().unwrap_or(0.0);
        let _ = (h1(a, 0.1, -0.05), h2(a, 0.1, -0.05));
    });
    println!(
        "   coordinator overhead = {:.3}% of a local step (target ≤ 5%)",
        100.0 * t_coord / (t_gh + kernel_ada)
    );

    println!("\n== raw-score pipeline scaling ==");
    for p in [2usize, 4, 8, 16] {
        let w = geometric_weights(p, 0.5);
        let mut tr = ScoreTracker::new(w);
        for i in 0..p + 1 {
            tr.observe_u(i as f64 * 0.1);
        }
        bench(&format!("raw score, history p={p}"), 200, || {
            tr.observe_u(0.5);
            let _ = tr.raw_score();
        });
    }
    Ok(())
}
