//! Bench: the DESIGN.md §6 ablations, on the closed-form quadratic engine
//! (mechanics-level: converges? corrections fired? — hundreds of simulated
//! rounds per second, no PJRT; the real-engine ordering lives in
//! fig4_fig5_grid and tests/xla_end_to_end.rs).
//!
//!   cargo bench --bench ablations
//!
//! Sweeps: detector sign, failure semantics, gossip mode, knee constant,
//! raw-score history depth p.

mod common;

use deahes::config::{EngineKind, ExperimentConfig, GossipMode};
use deahes::coordinator::failure::{FailStyle, FailureModel};
use deahes::coordinator::sim;
use deahes::elastic::weight::Detector;
use deahes::strategies::Method;

fn base() -> ExperimentConfig {
    ExperimentConfig {
        method: Method::DeahesO,
        workers: 4,
        tau: 2,
        rounds: 120,
        lr: 0.05,
        eval_every: 4,
        failure: FailureModel::Burst { p_start: 0.15, mean_len: 6.0 },
        engine: EngineKind::Quadratic { dim: 64, heterogeneity: 0.5, noise: 0.02 },
        ..ExperimentConfig::default()
    }
}

fn report(label: &str, cfg: &ExperimentConfig) -> anyhow::Result<()> {
    let r = sim::run(cfg)?;
    let last = r.log.records.last().unwrap();
    let corrections: u64 = r.worker_stats.iter().map(|s| s.1).sum();
    let served: u64 = r.worker_stats.iter().map(|s| s.0).sum();
    println!(
        "{label:<44} loss {:>9.4}  corrections {:>4}/{:<4} syncs  h2̄ {:>5.3}",
        last.test_loss,
        corrections,
        served,
        last.mean_h2,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    deahes::util::logging::init(deahes::util::logging::Level::Warn);

    println!("== ablation: raw-score sign convention (DESIGN.md §6.3) ==");
    for det in [Detector::PaperSign, Detector::DriftSign] {
        let mut cfg = base();
        cfg.detector = det;
        report(&format!("detector = {}", det.name()), &cfg)?;
    }

    println!("\n== ablation: failure semantics (DESIGN.md §6.4) ==");
    for style in [FailStyle::Node, FailStyle::Comm] {
        let mut cfg = base();
        cfg.fail_style = style;
        report(&format!("fail-style = {}", style.name()), &cfg)?;
    }

    println!("\n== ablation: gossip master-estimate source (§6.5) ==");
    for mode in [GossipMode::Peers, GossipMode::Stale] {
        let mut cfg = base();
        cfg.gossip = mode;
        report(&format!("gossip = {mode:?}"), &cfg)?;
    }

    println!("\n== ablation: knee constant k (§6.3) ==");
    for knee in [-0.01, -0.05, -0.2, -0.5] {
        let mut cfg = base();
        cfg.knee = knee;
        report(&format!("knee = {knee}"), &cfg)?;
    }

    println!("\n== ablation: raw-score history depth p (§6.6) ==");
    for p in [1usize, 2, 4, 8] {
        let mut cfg = base();
        cfg.score_p = p;
        report(&format!("score history p = {p}"), &cfg)?;
    }

    println!("\n== ablation: communication period tau (robustness, paper §VII) ==");
    for tau in [1usize, 2, 4, 8] {
        let mut cfg = base();
        cfg.tau = tau;
        report(&format!("tau = {tau}"), &cfg)?;
    }

    println!("\n(quad engine: mechanics only — see fig4_fig5_grid for real-engine ordering)");
    Ok(())
}
