//! Bench: the DESIGN.md §6 ablations, on the closed-form quadratic engine
//! (mechanics-level: converges? corrections fired? — hundreds of simulated
//! rounds per second, no PJRT; the real-engine ordering lives in
//! fig4_fig5_grid and tests/xla_end_to_end.rs).
//!
//!   cargo bench --bench ablations
//!   BENCH_JOBS=4 cargo bench --bench ablations          # trials in parallel
//!   BENCH_RUN_DIR=runs/abl BENCH_RESUME=1 ...           # resumable
//!
//! The whole battery compiles into ONE trial plan and executes through the
//! schedule engine, so every sweep axis shares the backend, committer and
//! run-sink machinery of the figure sweeps.
//!
//! Sweeps: detector sign, failure semantics, gossip mode, knee constant,
//! raw-score history depth p, communication period tau, fault scenarios
//! (no-kill straggler regime + elastic membership churn).

mod common;

use deahes::config::{EngineKind, ExperimentConfig, GossipMode};
use deahes::coordinator::failure::{FailStyle, FailureModel};
use deahes::elastic::weight::Detector;
use deahes::schedule::{self, TrialOutcome, TrialPlan};
use deahes::strategies::Method;

fn base() -> ExperimentConfig {
    ExperimentConfig {
        method: Method::DeahesO,
        workers: 4,
        tau: 2,
        rounds: 120,
        lr: 0.05,
        eval_every: 4,
        failure: FailureModel::Burst { p_start: 0.15, mean_len: 6.0 },
        engine: EngineKind::Quadratic { dim: 64, heterogeneity: 0.5, noise: 0.02 },
        ..ExperimentConfig::default()
    }
}

/// (section, label, config) — one trial per ablation point.
fn cases() -> Vec<(&'static str, String, ExperimentConfig)> {
    let mut out = Vec::new();

    for det in [Detector::PaperSign, Detector::DriftSign] {
        let mut cfg = base();
        cfg.detector = det;
        out.push((
            "raw-score sign convention (DESIGN.md §6.3)",
            format!("detector = {}", det.name()),
            cfg,
        ));
    }
    for style in [FailStyle::Node, FailStyle::Comm] {
        let mut cfg = base();
        cfg.fail_style = style;
        out.push((
            "failure semantics (DESIGN.md §6.4)",
            format!("fail-style = {}", style.name()),
            cfg,
        ));
    }
    for mode in [GossipMode::Peers, GossipMode::Stale] {
        let mut cfg = base();
        cfg.gossip = mode;
        out.push(("gossip master-estimate source (§6.5)", format!("gossip = {mode:?}"), cfg));
    }
    for knee in [-0.01, -0.05, -0.2, -0.5] {
        let mut cfg = base();
        cfg.knee = knee;
        out.push(("knee constant k (§6.3)", format!("knee = {knee}"), cfg));
    }
    for p in [1usize, 2, 4, 8] {
        let mut cfg = base();
        cfg.score_p = p;
        out.push(("raw-score history depth p (§6.6)", format!("score history p = {p}"), cfg));
    }
    for tau in [1usize, 2, 4, 8] {
        let mut cfg = base();
        cfg.tau = tau;
        out.push((
            "communication period tau (robustness, paper §VII)",
            format!("tau = {tau}"),
            cfg,
        ));
    }
    // Straggler regime: one worker at one-third speed, NO failures at all.
    // The sync-wait column goes nonuniform (the clock's wait stream sees the
    // straggler's long spans), and the staleness-aware policies must respond
    // where `fixed` cannot — this is the no-kill separation the scenario
    // subsystem exists to expose.
    for policy in ["fixed", "delayed(staleness_cap=4)", "adaptive"] {
        let mut cfg = base();
        cfg.failure = FailureModel::None;
        cfg.speeds = Some(vec![1.0, 1.0, 1.0, 3.0]);
        cfg.policy =
            Some(deahes::elastic::policy::canonical(policy).expect("literal policy spec"));
        out.push((
            "straggler, no kills (worker 3 at 1/3 speed)",
            format!("policy = {policy}"),
            cfg,
        ));
    }
    // Elastic membership churn: worker 3 leaves after round 29 and rejoins
    // at round 90, adopting the master estimate. Compared against the same
    // config at full membership.
    for (label, membership) in
        [("full membership", None), ("worker 3 out for rounds 30-89", Some("3=0-29+90-"))]
    {
        let mut cfg = base();
        cfg.membership = membership.map(str::to_string);
        out.push(("elastic membership churn", label.to_string(), cfg));
    }
    out
}

fn report(label: &str, o: &TrialOutcome) {
    let last = o.record.log.records.last().expect("trial produced records");
    let corrections: u64 = o.record.worker_stats.iter().map(|s| s.1).sum();
    let served: u64 = o.record.worker_stats.iter().map(|s| s.0).sum();
    println!(
        "{label:<44} loss {:>9.4}  corrections {:>4}/{:<4} syncs  h2̄ {:>5.3}  \
         wait {:>8.5}s/{:>8.5}s{}",
        last.test_loss,
        corrections,
        served,
        last.mean_h2,
        o.record.sim.mean_sync_wait,
        o.record.sim.p95_style_max_wait,
        if o.cached { "  [resumed]" } else { "" },
    );
}

fn main() -> anyhow::Result<()> {
    deahes::util::logging::init(deahes::util::logging::Level::Warn);

    let cases = cases();
    let mut plan = TrialPlan::new();
    for (section, label, cfg) in &cases {
        plan.push_cell(&format!("ablation/{section}/{label}"), label, cfg, 1);
    }
    let result = common::timed("ablation battery", || {
        schedule::execute_plan(&plan, &common::schedule_options())
    })?;

    let mut current_section = "";
    for ((section, label, _), outcome) in cases.iter().zip(&result.outcomes) {
        if *section != current_section {
            if !current_section.is_empty() {
                println!();
            }
            println!("== ablation: {section} ==");
            current_section = *section;
        }
        report(label, outcome);
    }

    println!(
        "\n[schedule] backend={} executed={} resumed={}",
        result.backend, result.executed, result.skipped
    );
    println!("(quad engine: mechanics only — see fig4_fig5_grid for real-engine ordering)");
    Ok(())
}
