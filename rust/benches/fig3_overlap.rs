//! Bench: regenerate the paper's **Fig. 3** — test accuracy over
//! communication rounds for data-overlap ratios r ∈ {0, 12.5, 25, 37.5, 50}%
//! on the AdaHessian + overlap method — swept over BOTH sync topologies
//! (central EASGD round-trips vs decentralized gossip elastic pull), so the
//! bench doubles as the straggler-free baseline comparison of the two modes.
//!
//!   cargo bench --bench fig3_overlap
//!   BENCH_SEEDS=1 BENCH_ROUNDS=30 cargo bench --bench fig3_overlap   # smoke
//!   BENCH_JOBS=4 BENCH_RUN_DIR=runs/fig3 ...                         # parallel + resumable
//!   BENCH_SYNC_MODES=central cargo bench --bench fig3_overlap        # one mode only
//!
//! Expected shape (paper): accuracy is non-decreasing in r — the shared
//! subset lowers the variance of per-worker Hessian estimates. Gossip mode
//! trails central slightly at equal rounds (its pulls run against a
//! one-round-delayed snapshot) but needs no blocking master round-trip.

mod common;

use deahes::config::SyncMode;
use deahes::experiments;
use deahes::metrics::ascii_chart;

fn main() -> anyhow::Result<()> {
    // cargo passes --bench; ignore argv entirely.
    let mut base = common::base_config();
    base.workers = 4;
    base.tau = 1;
    let ratios = [0.0, 0.125, 0.25, 0.375, 0.5];
    let seeds = common::seeds();
    // Unknown tokens are hard errors: a typo'd BENCH_SYNC_MODES must not
    // silently bench nothing and exit green.
    let modes_var = std::env::var("BENCH_SYNC_MODES").unwrap_or_else(|_| "central,gossip".into());
    let modes: Vec<SyncMode> = modes_var
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            SyncMode::parse(s)
                .ok_or_else(|| anyhow::anyhow!("BENCH_SYNC_MODES: unknown mode '{s}' (central|gossip)"))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!modes.is_empty(), "BENCH_SYNC_MODES resolved to an empty mode list");

    let opts = common::schedule_options();
    for mode in modes {
        base.sync_mode = mode;
        println!(
            "== Fig 3 reproduction [{} sync]: overlap ratios {ratios:?}, k=4, tau=1, \
             {seeds} seed(s), {} rounds ==",
            mode.name(),
            base.rounds
        );
        let out = common::timed(&format!("fig3 sweep ({})", mode.name()), || {
            experiments::fig3_overlap_sweep_with(&base, &ratios, seeds, &opts)
        })?;

        let chart: Vec<(&str, Vec<f64>)> =
            out.iter().map(|s| (s.label.as_str(), s.test_acc.clone())).collect();
        print!("{}", ascii_chart("Fig 3: test accuracy over rounds", &chart, 72, 16));

        println!("{:<10} {:>12} {:>14} {:>12}", "ratio", "tail acc", "(std)", "train loss");
        for s in &out {
            println!(
                "{:<10} {:>11.2}% {:>13.2}% {:>12.4}",
                s.label,
                100.0 * s.final_acc_mean,
                100.0 * s.final_acc_std,
                s.final_train_loss
            );
        }

        // Paper's qualitative claim: positive relationship between r and acc.
        let accs: Vec<f64> = out.iter().map(|s| s.final_acc_mean).collect();
        let xs: Vec<f64> = ratios.to_vec();
        let slope = deahes::util::stats::linear_slope(&xs, &accs);
        println!("\nacc-vs-ratio least-squares slope: {slope:+.4} (paper: positive)\n");
    }
    Ok(())
}
