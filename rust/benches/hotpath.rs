//! Hot-path bench driver: `cargo bench --bench hotpath`.
//!
//! Thin wrapper over `deahes::bench` (the same engine behind the
//! `deahes bench` subcommand) so the benchmark code is compiled by
//! `cargo bench --no-run` in CI and cannot rot. Env flags:
//!
//!   BENCH_SMOKE=1     tiny sizes (CI)
//!   BENCH_OUT=path    output JSON (default BENCH_hotpath.json)

fn main() -> anyhow::Result<()> {
    deahes::util::logging::init(deahes::util::logging::Level::Warn);
    let smoke = std::env::var("BENCH_SMOKE").as_deref() == Ok("1");
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let out = std::path::PathBuf::from(out);
    let doc = deahes::bench::run(&deahes::bench::BenchConfig { smoke }, &out)?;
    println!("{}", deahes::bench::summary(&doc));
    println!("[bench] wrote {}", out.display());
    Ok(())
}
