//! Integration: the full coordinator over the closed-form quadratic engine.
//!
//! These tests exercise the paper's algorithm end to end (hundreds of
//! rounds in milliseconds, no PJRT): convergence of every method, the
//! failure-mitigation claims, detector behaviour, driver equivalence and
//! determinism.

use deahes::config::{EngineKind, ExperimentConfig, GossipMode};
use deahes::coordinator::{sim, FailureModel};
use deahes::elastic::weight::Detector;
use deahes::strategies::{Method, ALL_METHODS};
use deahes::util::proptest;

fn quad_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 64, heterogeneity: 0.2, noise: 0.02 },
        workers: 4,
        tau: 2,
        rounds: 80,
        lr: 0.05,
        eval_subset: 8,
        eval_every: 4,
        ..ExperimentConfig::default()
    }
}

#[test]
fn every_method_reduces_global_loss() {
    for m in ALL_METHODS {
        let mut cfg = quad_cfg();
        cfg.method = m;
        let r = sim::run(&cfg).unwrap();
        let first = r.log.records.first().unwrap().test_loss;
        let last = r.log.records.last().unwrap().test_loss;
        assert!(
            last < 0.5 * first,
            "{}: loss {first} -> {last} did not halve",
            m.name()
        );
    }
}

#[test]
fn sequential_driver_is_deterministic() {
    let cfg = quad_cfg();
    let a = sim::run(&cfg).unwrap();
    let b = sim::run(&cfg).unwrap();
    assert_eq!(a.log.records.len(), b.log.records.len());
    for (x, y) in a.log.records.iter().zip(&b.log.records) {
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.syncs_failed, y.syncs_failed);
    }
}

#[test]
fn threaded_driver_converges_like_sequential() {
    let mut cfg = quad_cfg();
    cfg.rounds = 60;
    let seq = sim::run(&cfg).unwrap();
    cfg.threaded = true;
    let thr = sim::run(&cfg).unwrap();
    let s = seq.log.records.last().unwrap().test_loss;
    let t = thr.log.records.last().unwrap().test_loss;
    // same fault schedule, same hyperparams; arrival order differs, so only
    // statistical agreement is required.
    assert!(t < 2.5 * s + 0.05, "threaded {t} vs sequential {s}");
    // identical failure counts: the schedule is a pure function
    let sf: u32 = seq.log.records.iter().map(|r| r.syncs_failed).sum();
    let tf: u32 = thr.log.records.iter().map(|r| r.syncs_failed).sum();
    assert_eq!(sf, tf, "fault schedules diverged across drivers");
}

#[test]
fn dynamic_weighting_converges_and_fires_under_bursts() {
    // NOTE: the quadratic world cannot reproduce the paper's ORDERING —
    // staleness is benign under convexity (a stale model pulls the master
    // backwards briefly; convex descent instantly recovers), so fixed α
    // matches or beats mitigation here. The ordering claim is validated on
    // the real CNN engine (tests/xla_end_to_end.rs::paper_ordering_under_
    // burst_failures and the fig4/5 bench). This test pins the MECHANICS:
    // under bursty node-down failures the dynamic policy must still
    // converge and its failure branch must actually fire.
    let mut cfg = quad_cfg();
    cfg.method = Method::DeahesO;
    cfg.detector = Detector::PaperSign;
    cfg.failure = FailureModel::Burst { p_start: 0.25, mean_len: 6.0 };
    cfg.rounds = 100;
    cfg.engine = EngineKind::Quadratic { dim: 64, heterogeneity: 0.6, noise: 0.02 };
    let r = sim::run(&cfg).unwrap();
    let first = r.log.records.first().unwrap().test_loss;
    let last = r.log.records.last().unwrap().test_loss;
    assert!(last < 0.25 * first, "no convergence under bursts: {first} -> {last}");
    let corrections: u64 = r.worker_stats.iter().map(|s| s.1).sum();
    assert!(corrections > 0, "failure branch never fired under bursts");
}

#[test]
fn dynamic_corrections_target_the_failing_worker() {
    // Worker 2 fails in long bursts; the dynamic policy should correct its
    // syncs far more often than the healthy workers'.
    let mut cfg = quad_cfg();
    cfg.method = Method::DeahesO;
    cfg.rounds = 120;
    cfg.failure = FailureModel::Burst { p_start: 0.0, mean_len: 1.0 };
    // build a custom schedule: permanent-ish bursts for worker 2 only
    cfg.failure = FailureModel::Permanent { from_round: 20, workers: vec![2] };
    // permanent failure suppresses ALL of 2's syncs, so corrections can't
    // target it; use bursts via a mixed model instead: emulate by bernoulli
    // on worker 2 only is not expressible -> use burst with high start.
    cfg.failure = FailureModel::Burst { p_start: 0.15, mean_len: 8.0 };
    cfg.engine = EngineKind::Quadratic { dim: 64, heterogeneity: 0.6, noise: 0.02 };
    let r = sim::run(&cfg).unwrap();
    // At least: workers with more misses get more corrections in aggregate.
    let total_corrections: u64 = r.worker_stats.iter().map(|s| s.1).sum();
    assert!(total_corrections > 0, "dynamic policy never fired under bursts");
}

#[test]
fn paper_sign_detector_outperforms_drift_sign_under_bursts() {
    // The ablation that resolves the paper's sign ambiguity (DESIGN.md §6):
    // the as-printed convention (failure ⇔ a < k, fired by the
    // post-reconnect recovery dip) must end at least as well as the
    // naive drift-sign reading, which mistakes healthy transients for
    // failures, zeroes h2, and starves the master.
    let run_det = |detector: Detector| {
        let mut cfg = quad_cfg();
        cfg.method = Method::DeahesO;
        cfg.detector = detector;
        cfg.rounds = 100;
        cfg.failure = FailureModel::Burst { p_start: 0.2, mean_len: 6.0 };
        cfg.engine = EngineKind::Quadratic { dim: 64, heterogeneity: 0.6, noise: 0.02 };
        sim::run(&cfg).unwrap()
    };
    let drift = run_det(Detector::DriftSign);
    let paper = run_det(Detector::PaperSign);
    let ld = drift.log.records.last().unwrap().test_loss;
    let lp = paper.log.records.last().unwrap().test_loss;
    assert!(lp <= ld * 1.1, "paper-sign {lp} worse than drift-sign {ld}");
}

#[test]
fn overlap_reduces_heterogeneity_penalty() {
    // With the quadratic engine, heterogeneity plays the role the data
    // distribution plays on the real corpus. More workers pulling toward
    // private optima hurt the master; elastic + dynamic weighting should
    // still converge.
    let mut cfg = quad_cfg();
    cfg.method = Method::DeahesO;
    cfg.engine = EngineKind::Quadratic { dim: 64, heterogeneity: 0.8, noise: 0.02 };
    cfg.rounds = 100;
    let r = sim::run(&cfg).unwrap();
    let first = r.log.records.first().unwrap().test_loss;
    let last = r.log.records.last().unwrap().test_loss;
    assert!(last < first, "no progress under heterogeneity");
}

#[test]
fn gossip_modes_both_work() {
    for mode in [GossipMode::Peers, GossipMode::Stale] {
        let mut cfg = quad_cfg();
        cfg.gossip = mode;
        cfg.method = Method::DeahesO;
        let r = sim::run(&cfg).unwrap();
        assert!(r.log.records.last().unwrap().test_loss.is_finite());
    }
}

#[test]
fn config_json_roundtrip_reproduces_run() {
    let cfg = quad_cfg();
    let json_text = cfg.to_json().to_string_pretty();
    let parsed = deahes::util::json::Json::parse(&json_text).unwrap();
    let cfg2 = ExperimentConfig::from_json(&parsed).unwrap();
    let a = sim::run(&cfg).unwrap();
    let b = sim::run(&cfg2).unwrap();
    assert_eq!(
        a.log.records.last().unwrap().test_loss.to_bits(),
        b.log.records.last().unwrap().test_loss.to_bits()
    );
}

#[test]
fn property_sim_invariants_hold_across_random_configs() {
    proptest::check("sim invariants", 15, |g| {
        let mut cfg = quad_cfg();
        cfg.workers = g.usize(1, 6);
        cfg.tau = g.usize(1, 4);
        cfg.rounds = g.usize(4, 20) as u64;
        cfg.eval_every = g.usize(1, 3) as u64;
        cfg.method = *g.pick(&ALL_METHODS);
        cfg.seed = g.u64();
        cfg.failure = FailureModel::Bernoulli { p: g.f64(0.0, 0.6) };
        cfg.engine = EngineKind::Quadratic {
            dim: g.usize(4, 64),
            heterogeneity: g.f64(0.0, 0.5),
            noise: g.f64(0.0, 0.1),
        };
        let r = sim::run(&cfg).unwrap();
        // invariant: per round, ok + failed == workers
        for rec in &r.log.records {
            assert_eq!(rec.syncs_ok + rec.syncs_failed, cfg.workers as u32);
            assert!(rec.test_loss.is_finite());
            assert!(rec.train_loss.is_finite());
        }
        // invariant: served syncs counted by master == sum of ok per round
        // (only equal when every round is recorded)
        if cfg.eval_every == 1 {
            let ok_total: u64 = r.log.records.iter().map(|x| x.syncs_ok as u64).sum();
            let served: u64 = r.worker_stats.iter().map(|s| s.0).sum();
            assert_eq!(ok_total, served);
        }
        // invariant: last record is the final round
        assert_eq!(r.log.records.last().unwrap().round, cfg.rounds - 1);
    });
}
