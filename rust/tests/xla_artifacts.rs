//! Integration: the AOT artifacts through PJRT vs the rust-native mirrors.
//!
//! This is the three-way correctness chain's final link: pytest already
//! pins pallas == jnp (python side); these tests pin artifact == native
//! rust, so pallas == jnp == rust holds transitively on the exact graphs
//! the coordinator executes.
//!
//! Requires `make artifacts` (skipped gracefully if absent — CI runs make
//! first).

use deahes::engine::xla::{OptimImpl, XlaEngine};
use deahes::engine::{BatchRef, Engine};
use deahes::optim::native;
use deahes::runtime::Manifest;
use deahes::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new("artifacts");
    match Manifest::load(dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e:#}");
            None
        }
    }
}

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let denom = 1.0f32.max(x.abs()).max(y.abs());
        worst = worst.max((x - y).abs() / denom);
    }
    assert!(worst <= tol, "{what}: max rel err {worst} > {tol}");
}

fn batch(manifest: &Manifest, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    let bt = manifest.batch_train;
    let x: Vec<f32> = (0..bt * 28 * 28).map(|_| rng.f32()).collect();
    let mut y = vec![0.0f32; bt * 10];
    for r in 0..bt {
        y[r * 10 + (r % 10)] = 1.0;
    }
    (x, y)
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(m) = manifest() else { return };
    assert_eq!(m.model, "cnn-paper");
    assert_eq!(m.param_count, 9098);
    assert_eq!(m.artifacts.len(), 7);
    // conv segments cover 3x3 blocks
    for c in &m.conv_segments {
        assert_eq!(c.block, 9);
    }
}

#[test]
fn optimizer_kernels_match_native_mirrors() {
    let Some(m) = manifest() else { return };
    let mut engine = XlaEngine::new(&m, OptimImpl::Kernels).unwrap();
    let n = m.param_count;
    let mut rng = Rng::new(1);
    let theta0 = rand_vec(&mut rng, n, 0.5);
    let g = rand_vec(&mut rng, n, 0.1);
    let d: Vec<f32> = rand_vec(&mut rng, n, 0.5).iter().map(|x| x.abs()).collect();

    // sgd
    let mut a = theta0.clone();
    engine.sgd(&mut a, &g, 0.05).unwrap();
    let mut b = theta0.clone();
    native::sgd_step(&mut b, &g, 0.05);
    assert_close(&a, &b, 1e-6, "sgd");

    // momentum (mu baked = manifest hyperparam)
    let mut a = theta0.clone();
    let mut abuf = rand_vec(&mut rng, n, 0.1);
    let bbuf0 = abuf.clone();
    engine.momentum(&mut a, &g, &mut abuf, 0.05).unwrap();
    let mut b = theta0.clone();
    let mut bbuf = bbuf0;
    native::momentum_step(&mut b, &g, &mut bbuf, 0.05, m.hyperparams.momentum as f32);
    assert_close(&a, &b, 1e-6, "momentum.theta");
    assert_close(&abuf, &bbuf, 1e-6, "momentum.buf");

    // adahessian across several steps (bias correction exercises t)
    let mut a = theta0.clone();
    let (mut am, mut av) = (vec![0.0f32; n], vec![0.0f32; n]);
    let mut b = theta0.clone();
    let (mut bm, mut bv) = (vec![0.0f32; n], vec![0.0f32; n]);
    for t in 1..=5u64 {
        engine.adahessian(&mut a, &g, &d, &mut am, &mut av, t, 0.01).unwrap();
        native::adahessian_step(
            &mut b, &g, &d, &mut bm, &mut bv, t, 0.01,
            m.hyperparams.beta1 as f32,
            m.hyperparams.beta2 as f32,
            m.hyperparams.eps as f32,
        );
    }
    assert_close(&a, &b, 5e-4, "adahessian.theta");
    assert_close(&am, &bm, 5e-4, "adahessian.m");
    assert_close(&av, &bv, 5e-4, "adahessian.v");

    // elastic
    let mut aw = theta0.clone();
    let mut amr = rand_vec(&mut rng, n, 0.5);
    let (mut bw, mut bmr) = (aw.clone(), amr.clone());
    engine.elastic(&mut aw, &mut amr, 0.1, 0.07).unwrap();
    native::elastic_step(&mut bw, &mut bmr, 0.1, 0.07);
    assert_close(&aw, &bw, 1e-6, "elastic.worker");
    assert_close(&amr, &bmr, 1e-6, "elastic.master");
}

#[test]
fn grad_hess_consistent_with_grad() {
    let Some(m) = manifest() else { return };
    let mut engine = XlaEngine::new(&m, OptimImpl::Kernels).unwrap();
    let mut rng = Rng::new(2);
    let theta = m.init_theta(3);
    let (x, y) = batch(&m, &mut rng);
    let z = rng.rademacher(m.param_count);
    let n = m.param_count;
    let mut g1 = vec![0.0f32; n];
    let l1 = engine.grad(&theta, BatchRef { x: &x, y1h: &y }, &mut g1).unwrap();
    let mut g2 = vec![0.0f32; n];
    let mut d = vec![0.0f32; n];
    let l2 = engine
        .grad_hess(&theta, BatchRef { x: &x, y1h: &y }, &z, &mut g2, &mut d)
        .unwrap();
    assert!((l1 - l2).abs() < 1e-4, "loss mismatch {l1} vs {l2}");
    assert_close(&g1, &g2, 1e-4, "grad");
    assert!(d.iter().all(|v| v.is_finite()));
    // spatial averaging: conv blocks are constant
    for c in &m.conv_segments {
        for b in 0..c.n_blocks {
            let s = c.offset + b * c.block;
            let first = d[s];
            for i in 1..c.block {
                assert!(
                    (d[s + i] - first).abs() <= 1e-4 * first.abs().max(1.0),
                    "conv block {b} not averaged"
                );
            }
        }
    }
}

#[test]
fn grad_matches_finite_difference_spot_check() {
    let Some(m) = manifest() else { return };
    let mut engine = XlaEngine::new(&m, OptimImpl::Kernels).unwrap();
    let mut rng = Rng::new(4);
    let theta = m.init_theta(5);
    let (x, y) = batch(&m, &mut rng);
    let mut g = vec![0.0f32; m.param_count];
    engine.grad(&theta, BatchRef { x: &x, y1h: &y }, &mut g).unwrap();
    // central differences on a few random coordinates
    let mut idx_rng = Rng::new(6);
    let mut scratch_g = vec![0.0f32; m.param_count];
    for _ in 0..4 {
        let i = idx_rng.usize_below(m.param_count);
        let eps = 2e-3f32;
        let mut tp = theta.clone();
        tp[i] += eps;
        let lp = engine.grad(&tp, BatchRef { x: &x, y1h: &y }, &mut scratch_g).unwrap();
        let mut tm = theta.clone();
        tm[i] -= eps;
        let lm = engine.grad(&tm, BatchRef { x: &x, y1h: &y }, &mut scratch_g).unwrap();
        let fd = (lp - lm) / (2.0 * eps);
        let tol = 0.1 * fd.abs().max(0.02);
        assert!(
            (fd - g[i]).abs() < tol,
            "coord {i}: fd {fd} vs grad {}",
            g[i]
        );
    }
}

#[test]
fn eval_counts_match_manual_argmax() {
    let Some(m) = manifest() else { return };
    let mut engine = XlaEngine::new(&m, OptimImpl::Kernels).unwrap();
    let theta = m.init_theta(7);
    let be = m.batch_eval;
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..be * 28 * 28).map(|_| rng.f32()).collect();
    let mut y = vec![0.0f32; be * 10];
    for r in 0..be {
        y[r * 10 + (r % 10)] = 1.0;
    }
    let (correct, sum_loss) = engine.eval(&theta, BatchRef { x: &x, y1h: &y }).unwrap();
    assert!((0.0..=be as f32).contains(&correct));
    assert!(sum_loss > 0.0 && sum_loss.is_finite());
    // untrained uniform-ish model: accuracy near 1/10
    let acc = correct / be as f32;
    assert!(acc < 0.5, "untrained model suspiciously accurate: {acc}");
}

#[test]
fn native_opt_engine_matches_kernel_engine_over_a_round() {
    let Some(m) = manifest() else { return };
    let mut ek = XlaEngine::new(&m, OptimImpl::Kernels).unwrap();
    let mut en = XlaEngine::new(&m, OptimImpl::Native).unwrap();
    let n = m.param_count;
    let mut rng = Rng::new(9);
    let (x, y) = batch(&m, &mut rng);
    let z = rng.rademacher(n);
    let mut tk = m.init_theta(1);
    let mut tn = tk.clone();
    let (mut mk, mut vk) = (vec![0.0; n], vec![0.0; n]);
    let (mut mn, mut vn) = (vec![0.0; n], vec![0.0; n]);
    let (mut gk, mut dk) = (vec![0.0f32; n], vec![0.0f32; n]);
    let (mut gn, mut dn) = (vec![0.0f32; n], vec![0.0f32; n]);
    for t in 1..=3u64 {
        ek.grad_hess(&tk, BatchRef { x: &x, y1h: &y }, &z, &mut gk, &mut dk).unwrap();
        ek.adahessian(&mut tk, &gk, &dk, &mut mk, &mut vk, t, 0.05).unwrap();
        en.grad_hess(&tn, BatchRef { x: &x, y1h: &y }, &z, &mut gn, &mut dn).unwrap();
        en.adahessian(&mut tn, &gn, &dn, &mut mn, &mut vn, t, 0.05).unwrap();
    }
    // Tolerance note: the kernel computes bias correction as exp(t·ln β)
    // while the mirror uses β^t, and early steps divide by sqrt(v)+eps with
    // v ≈ 0 — tiny f32 differences amplify over the trajectory. 1% after
    // three full grad+update steps is the expected envelope.
    assert_close(&tk, &tn, 1e-2, "kernel-vs-native trajectory");
}
