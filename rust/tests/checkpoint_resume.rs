//! Crash-safe mid-trial checkpoint/resume.
//!
//! The headline guarantee (ISSUE 4 acceptance): killing a run mid-trial
//! and resuming from its checkpoint produces a `RunResult` byte-identical
//! to the uninterrupted run, for every registered policy, on the quad
//! engine. Three layers are pinned here:
//!
//!  1. driver level — `sim::run_with(cfg, Some(checkpoint), _)` continues
//!     bit-exactly from any boundary the hooks captured (and capturing
//!     checkpoints is observation-only: it changes no numbers);
//!  2. schedule level — a trial killed by crash injection after writing a
//!     checkpoint resumes through `execute_plan(resume: true)` and commits
//!     the same record bytes an uninterrupted run commits;
//!  3. CLI level — `experiments::resume_run_dir` (the `deahes resume`
//!     engine) finishes half-run trials and re-materializes series from
//!     `runs.jsonl` alone.
//!
//! The threaded driver is covered as a smoke test: its checkpoint is a
//! consistent cut, but continuation has the driver's usual arrival-order
//! nondeterminism (see docs/ARCHITECTURE.md), so only driver-invariant
//! facts (fault schedule, record counts) are asserted.

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::coordinator::checkpoint::RunCheckpoint;
use deahes::coordinator::sim::{self, CheckpointHooks};
use deahes::experiments;
use deahes::schedule::{self, JsonlRunSink, ScheduleOptions, TrialPlan};
use deahes::strategies::Method;
use deahes::util::json::Json;
use std::path::{Path, PathBuf};

/// Every registered policy with an optimizer exercising each OptState
/// variant at least once (sgd, momentum, adahessian).
const POLICY_MATRIX: &[(&str, Method)] = &[
    ("fixed(alpha=0.1)", Method::Easgd),
    ("oracle(alpha=0.1)", Method::Eamsgd),
    ("dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)", Method::DeahesO),
    ("hysteresis(alpha=0.1,knee=-0.05,detector=paper-sign,hold=2)", Method::DeahesO),
    ("staleness(alpha=0.1,halflife=2)", Method::Easgd),
    ("delayed(alpha=0.1,staleness_cap=3)", Method::Eamsgd),
    ("adaptive(alpha0=0.1,window=4)", Method::Easgd),
];

fn quad_cfg(policy: &str, method: Method) -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 24, heterogeneity: 0.3, noise: 0.05 },
        method,
        workers: 3,
        tau: 2,
        rounds: 21,
        eval_subset: 16,
        policy: Some(policy.to_string()),
        ..ExperimentConfig::default()
    }
}

/// Exactly the deterministic content the sink's `TrialRecord` persists:
/// canonicalized log + sim report + worker stats; wall-clock and perf text
/// excluded by design.
fn digest(r: &sim::RunResult) -> String {
    let mut log = r.log.clone();
    log.canonicalize_non_finite();
    Json::obj(vec![
        ("records", log.to_json()),
        ("sim", r.sim.to_json()),
        ("worker_stats", Json::arr_u64_pairs(&r.worker_stats)),
    ])
    .to_string_compact()
}

fn capture_checkpoints(
    cfg: &ExperimentConfig,
    every: u64,
) -> (sim::RunResult, Vec<RunCheckpoint>) {
    let mut cps: Vec<RunCheckpoint> = Vec::new();
    let mut save = |cp: RunCheckpoint| -> anyhow::Result<()> {
        cps.push(cp);
        Ok(())
    };
    let r = sim::run_with(
        cfg,
        None,
        Some(CheckpointHooks { every, every_secs: 0.0, save: &mut save }),
    )
    .unwrap();
    (r, cps)
}

/// The acceptance pin: for each registered policy, run N rounds, kill,
/// restore, run to completion — byte-identical `RunResult` vs an
/// uninterrupted run, from EVERY checkpoint boundary.
#[test]
fn resume_is_bit_identical_for_every_policy_on_the_quad_engine() {
    for &(policy, method) in POLICY_MATRIX {
        let cfg = quad_cfg(policy, method);
        let baseline = digest(&sim::run(&cfg).unwrap());
        let (hooked, cps) = capture_checkpoints(&cfg, 8);
        assert_eq!(digest(&hooked), baseline, "{policy}: capturing checkpoints changed numbers");
        assert_eq!(cps.len(), 2, "{policy}: rounds=21, every=8 -> cuts at 8 and 16");
        for cp in &cps {
            let round = cp.next_round;
            // restore from the in-memory checkpoint...
            let resumed = sim::run_with(&cfg, Some(cp), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{policy}: resume from round {round} diverged"
            );
            // ...and from its JSON round-trip (what the sink actually stores)
            let reread =
                RunCheckpoint::from_json(&Json::parse(&cp.to_json().to_string_compact()).unwrap())
                    .unwrap();
            let resumed = sim::run_with(&cfg, Some(&reread), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{policy}: resume from persisted round-{round} checkpoint diverged"
            );
        }
    }
}

/// Gossip-mode acceptance pin: the decentralized topology (per-worker
/// policies, pull cursors, master snapshot slot, replica board) restores
/// bit-exactly from every boundary, for the two new policies and for the
/// AdamW preset — the sequential quad continuation is byte-identical.
#[test]
fn gossip_resume_is_bit_identical_for_the_new_policies_and_adamw() {
    use deahes::config::SyncMode;
    for (policy, method, optimizer) in [
        ("delayed(alpha=0.1,staleness_cap=3)", Method::Easgd, None),
        ("adaptive(alpha0=0.1,window=4)", Method::DeahesO, None),
        // AdamW preset through the same pin (covers OptState::AdamW
        // snapshots riding inside a gossip checkpoint).
        (
            "adaptive(alpha0=0.1,window=4)",
            Method::Easgd,
            Some("adamw(lr=0.02,beta1=0.9,beta2=0.999,eps=0.00000001,wd=0.01)"),
        ),
    ] {
        let mut cfg = quad_cfg(policy, method);
        cfg.sync_mode = SyncMode::Gossip;
        cfg.optimizer = optimizer.map(|s| s.to_string());
        let baseline = digest(&sim::run(&cfg).unwrap());
        let (hooked, cps) = capture_checkpoints(&cfg, 8);
        assert_eq!(
            digest(&hooked),
            baseline,
            "{policy}: capturing gossip checkpoints changed numbers"
        );
        assert_eq!(cps.len(), 2, "{policy}: rounds=21, every=8 -> cuts at 8 and 16");
        for cp in &cps {
            assert_eq!(cp.sync_mode(), SyncMode::Gossip, "{policy}: checkpoint missing mode tag");
            let round = cp.next_round;
            let resumed = sim::run_with(&cfg, Some(cp), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{policy} optimizer={optimizer:?}: gossip resume from round {round} diverged"
            );
            // ...and from the JSON round-trip the sink actually stores
            let reread =
                RunCheckpoint::from_json(&Json::parse(&cp.to_json().to_string_compact()).unwrap())
                    .unwrap();
            let resumed = sim::run_with(&cfg, Some(&reread), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{policy}: resume from persisted round-{round} gossip checkpoint diverged"
            );
        }
    }
}

/// Mixed-mode resume is a hard error with a clear message, both ways:
/// a central checkpoint cannot continue a gossip config and vice versa.
#[test]
fn mixed_mode_resume_is_a_hard_error() {
    use deahes::config::SyncMode;
    let central_cfg = quad_cfg("fixed(alpha=0.1)", Method::Easgd);
    let (_, central_cps) = capture_checkpoints(&central_cfg, 8);
    let mut gossip_cfg = central_cfg.clone();
    gossip_cfg.sync_mode = SyncMode::Gossip;
    let (_, gossip_cps) = capture_checkpoints(&gossip_cfg, 8);

    // central checkpoint -> gossip config
    let err = sim::run_with(&gossip_cfg, Some(&central_cps[0]), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("sync_mode=central"), "{err}");
    assert!(err.contains("sync_mode=gossip"), "{err}");
    assert!(err.contains("mixed-mode"), "{err}");
    // gossip checkpoint -> central config
    let err = sim::run_with(&central_cfg, Some(&gossip_cps[0]), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("mixed-mode"), "{err}");
    // the threaded driver refuses just the same
    let mut threaded_gossip = gossip_cfg.clone();
    threaded_gossip.threaded = true;
    let (_, thr_cps) = capture_checkpoints(&threaded_gossip, 8);
    let mut threaded_central = central_cfg.clone();
    threaded_central.threaded = true;
    let err = sim::run_with(&threaded_central, Some(&thr_cps[0]), None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("mixed-mode"), "{err}");
}

/// Threaded gossip smoke: the cut is consistent and a resume completes
/// with the driver-invariant facts intact (the pull schedule is a pure
/// function of (seed, worker, round) even across the resume boundary).
#[test]
fn threaded_gossip_driver_checkpoints_and_resumes() {
    use deahes::config::SyncMode;
    let mut cfg = quad_cfg("adaptive(alpha0=0.1,window=4)", Method::DeahesO);
    cfg.rounds = 18;
    cfg.threaded = true;
    cfg.sync_mode = SyncMode::Gossip;
    let (full, cps) = capture_checkpoints(&cfg, 6);
    assert_eq!(cps.len(), 2, "rounds=18, every=6 -> cuts at 6 and 12");
    let resumed = sim::run_with(&cfg, Some(&cps[1]), None).unwrap();
    assert_eq!(resumed.log.records.len(), full.log.records.len());
    let mut seq_cfg = cfg.clone();
    seq_cfg.threaded = false;
    let seq = sim::run(&seq_cfg).unwrap();
    for (a, b) in resumed.log.records.iter().zip(&seq.log.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            (a.syncs_ok, a.syncs_failed),
            (b.syncs_ok, b.syncs_failed),
            "pull schedule diverged at round {} across the resume boundary",
            a.round
        );
    }
    let served_resumed: Vec<u64> = resumed.worker_stats.iter().map(|s| s.0).collect();
    let served_seq: Vec<u64> = seq.worker_stats.iter().map(|s| s.0).collect();
    assert_eq!(served_resumed, served_seq);
}

#[test]
fn checkpoints_refuse_the_wrong_driver_and_shape() {
    let cfg = quad_cfg("fixed(alpha=0.1)", Method::Easgd);
    let (_, cps) = capture_checkpoints(&cfg, 8);
    // wrong driver
    let mut threaded_cfg = cfg.clone();
    threaded_cfg.threaded = true;
    assert!(sim::run_with(&threaded_cfg, Some(&cps[0]), None).is_err());
    // wrong worker count
    let mut fat_cfg = cfg.clone();
    fat_cfg.workers = 4;
    assert!(sim::run_with(&fat_cfg, Some(&cps[0]), None).is_err());
}

/// Threaded-driver smoke: the cut is consistent and a resume completes
/// with the driver-invariant facts intact (fault schedule is a pure
/// function of (seed, worker, round), so per-round sync counts must match
/// the sequential run's exactly even across the resume boundary).
#[test]
fn threaded_driver_checkpoints_and_resumes() {
    let mut cfg = quad_cfg("dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)", Method::DeahesO);
    cfg.rounds = 18;
    cfg.threaded = true;
    let (full, cps) = capture_checkpoints(&cfg, 6);
    assert_eq!(cps.len(), 2, "rounds=18, every=6 -> cuts at 6 and 12");
    let resumed = sim::run_with(&cfg, Some(&cps[1]), None).unwrap();
    assert_eq!(resumed.log.records.len(), full.log.records.len());
    let mut seq_cfg = cfg.clone();
    seq_cfg.threaded = false;
    let seq = sim::run(&seq_cfg).unwrap();
    for (a, b) in resumed.log.records.iter().zip(&seq.log.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(
            (a.syncs_ok, a.syncs_failed),
            (b.syncs_ok, b.syncs_failed),
            "fault schedule diverged at round {} across the resume boundary",
            a.round
        );
    }
    let served_resumed: Vec<u64> = resumed.worker_stats.iter().map(|s| s.0).collect();
    let served_seq: Vec<u64> = seq.worker_stats.iter().map(|s| s.0).collect();
    assert_eq!(served_resumed, served_seq);
}

/// The chunked parallel tier rides inside the byte-identity contract twice
/// over: (a) a run with `intra_parallel` enabled digests identically to the
/// same config without it (chunked kernels are bit-identical to scalar), and
/// (b) checkpoint/resume of the chunked config is itself byte-identical from
/// every boundary. Uses a dimension spanning multiple NOISE_BLOCK chunks so
/// the multi-chunk dispatch path is the one under test.
#[test]
fn intra_parallel_runs_digest_identically_and_resume_byte_exactly() {
    use deahes::config::SyncMode;
    for sync_mode in [SyncMode::Central, SyncMode::Gossip] {
        let mut scalar_cfg =
            quad_cfg("dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)", Method::DeahesO);
        scalar_cfg.engine = EngineKind::Quadratic { dim: 2100, heterogeneity: 0.3, noise: 0.05 };
        scalar_cfg.rounds = 12;
        scalar_cfg.sync_mode = sync_mode;
        let mut chunked_cfg = scalar_cfg.clone();
        // threshold 1: every dim qualifies, so the engines and the gossip
        // elastic kernels all run through the chunked dispatch
        chunked_cfg.intra_parallel = Some(1);

        let baseline = digest(&sim::run(&scalar_cfg).unwrap());
        let chunked = digest(&sim::run(&chunked_cfg).unwrap());
        assert_eq!(chunked, baseline, "{sync_mode:?}: chunked tier changed run numbers");

        let (hooked, cps) = capture_checkpoints(&chunked_cfg, 5);
        assert_eq!(digest(&hooked), baseline, "{sync_mode:?}: chunked checkpointing changed numbers");
        assert_eq!(cps.len(), 2, "{sync_mode:?}: rounds=12, every=5 -> cuts at 5 and 10");
        for cp in &cps {
            let resumed = sim::run_with(&chunked_cfg, Some(cp), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{sync_mode:?}: chunked resume from round {} diverged",
                cp.next_round
            );
        }
    }
}

/// A failing checkpoint save aborts the threaded drivers promptly: the
/// monitor poisons the barrier edge, every worker exits at its next round
/// boundary, and the save hook is never invoked a second time. Covers both
/// the central and the gossip threaded drivers.
#[test]
fn threaded_drivers_abort_on_checkpoint_save_failure() {
    use deahes::config::SyncMode;
    for sync_mode in [SyncMode::Central, SyncMode::Gossip] {
        let mut cfg = quad_cfg("fixed(alpha=0.1)", Method::Easgd);
        cfg.rounds = 18;
        cfg.threaded = true;
        cfg.sync_mode = sync_mode;
        let mut calls = 0u32;
        let mut save = |_cp: RunCheckpoint| -> anyhow::Result<()> {
            calls += 1;
            anyhow::bail!("disk full (injected)")
        };
        // `{:#}` prints the whole context chain — the driver wraps the
        // hook's error in "mid-trial checkpointing failed".
        let err = format!(
            "{:#}",
            sim::run_with(
                &cfg,
                None,
                Some(CheckpointHooks { every: 6, every_secs: 0.0, save: &mut save })
            )
            .unwrap_err()
        );
        assert!(err.contains("mid-trial checkpointing failed"), "{sync_mode:?}: {err}");
        assert!(err.contains("disk full (injected)"), "{sync_mode:?}: {err}");
        assert_eq!(
            calls, 1,
            "{sync_mode:?}: save hook must not be called again after a failure"
        );
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deahes-ckptres-{}-{name}", std::process::id()))
}

fn record_lines(dir: &Path) -> Vec<String> {
    JsonlRunSink::load(&dir.join(schedule::RUNS_FILE))
        .unwrap()
        .values()
        .map(|r| r.to_json().to_string_compact())
        .collect()
}

fn one_slot_plan() -> TrialPlan {
    let spec = "hysteresis(alpha=0.1,knee=-0.05,detector=paper-sign,hold=2)";
    let mut cfg = quad_cfg(spec, Method::DeahesO);
    cfg.rounds = 30;
    let mut plan = TrialPlan::new();
    plan.push_cell("ckpt/cell", "cell", &cfg, 1);
    plan
}

/// Schedule level: crash injection kills the trial right after its first
/// checkpoint lands in runs.jsonl; `--resume` finishes it from there and
/// the committed record is byte-identical to an uninterrupted run's.
#[test]
fn killed_trial_resumes_from_its_checkpoint_at_the_schedule_level() {
    let crash_dir = tmp_dir("crash");
    let clean_dir = tmp_dir("clean");
    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
    let plan = one_slot_plan();

    // uninterrupted reference
    let clean_opts = ScheduleOptions {
        run_dir: Some(clean_dir.clone()),
        ..ScheduleOptions::default()
    };
    schedule::execute_plan(&plan, &clean_opts).unwrap();

    // crash after the first checkpoint (round 8 of 30)
    let crash_opts = ScheduleOptions {
        run_dir: Some(crash_dir.clone()),
        checkpoint_every: 8,
        crash_after_checkpoints: 1,
        ..ScheduleOptions::default()
    };
    let err = schedule::execute_plan(&plan, &crash_opts).unwrap_err().to_string();
    assert!(err.contains("crash injection"), "{err}");
    assert!(record_lines(&crash_dir).is_empty(), "the killed trial must not have committed");

    // resume: the trial continues from round 8, commits, matches the clean run
    let resume_opts = ScheduleOptions {
        run_dir: Some(crash_dir.clone()),
        resume: true,
        checkpoint_every: 8,
        ..ScheduleOptions::default()
    };
    let report = schedule::execute_plan(&plan, &resume_opts).unwrap();
    assert_eq!(report.executed, 1);
    assert_eq!(report.skipped, 0);
    assert_eq!(
        record_lines(&crash_dir),
        record_lines(&clean_dir),
        "resumed record must be byte-identical to the uninterrupted run's"
    );

    // a further resume is a pure cache hit
    let again = schedule::execute_plan(&plan, &resume_opts).unwrap();
    assert_eq!((again.executed, again.skipped), (0, 1));

    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
}

/// CLI level: `deahes resume <run-dir>` (via `experiments::resume_run_dir`)
/// needs nothing but the run directory — identity and config come from the
/// checkpoint records themselves.
#[test]
fn resume_run_dir_finishes_pending_trials_and_rebuilds_series() {
    let crash_dir = tmp_dir("cli-crash");
    let clean_dir = tmp_dir("cli-clean");
    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
    let plan = one_slot_plan();

    schedule::execute_plan(
        &plan,
        &ScheduleOptions { run_dir: Some(clean_dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    let crash_opts = ScheduleOptions {
        run_dir: Some(crash_dir.clone()),
        checkpoint_every: 8,
        crash_after_checkpoints: 1,
        ..ScheduleOptions::default()
    };
    assert!(schedule::execute_plan(&plan, &crash_opts).is_err());

    let report = experiments::resume_run_dir(&crash_dir, 1).unwrap();
    assert_eq!(report.committed, 0);
    assert_eq!(report.finished, 1);
    assert_eq!(report.series.len(), 1);
    assert_eq!(report.series[0].label, "ckpt/cell", "series label is the cell key");
    assert_eq!(record_lines(&crash_dir), record_lines(&clean_dir));

    // resuming a fully-committed dir is a no-op that still yields series
    let report = experiments::resume_run_dir(&crash_dir, 1).unwrap();
    assert_eq!(report.committed, 1);
    assert_eq!(report.finished, 0);
    // and an empty/missing dir is a clear error
    assert!(experiments::resume_run_dir(&tmp_dir("nonexistent"), 1).is_err());

    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
}

/// The acceptance path: a gossip-mode trial killed after its first
/// checkpoint is finished by `deahes resume <run-dir>`
/// (`experiments::resume_run_dir`) and commits record bytes identical to
/// an uninterrupted run's — the gossip `sync` payload survives the full
/// JSONL round trip through the schedule layer.
#[test]
fn killed_gossip_trial_resumes_byte_identically_via_resume_run_dir() {
    use deahes::config::SyncMode;
    let crash_dir = tmp_dir("gossip-crash");
    let clean_dir = tmp_dir("gossip-clean");
    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
    let mut cfg = quad_cfg("delayed(alpha=0.1,staleness_cap=3)", Method::Easgd);
    cfg.rounds = 30;
    cfg.sync_mode = SyncMode::Gossip;
    let mut plan = TrialPlan::new();
    plan.push_cell("gossip-ckpt/cell", "cell", &cfg, 1);

    schedule::execute_plan(
        &plan,
        &ScheduleOptions { run_dir: Some(clean_dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    let crash_opts = ScheduleOptions {
        run_dir: Some(crash_dir.clone()),
        checkpoint_every: 8,
        crash_after_checkpoints: 1,
        ..ScheduleOptions::default()
    };
    assert!(schedule::execute_plan(&plan, &crash_opts).is_err());
    assert!(record_lines(&crash_dir).is_empty(), "the killed trial must not have committed");

    let report = experiments::resume_run_dir(&crash_dir, 1).unwrap();
    assert_eq!((report.committed, report.finished), (0, 1));
    assert_eq!(
        record_lines(&crash_dir),
        record_lines(&clean_dir),
        "resumed gossip record must be byte-identical to the uninterrupted run's"
    );

    let _ = std::fs::remove_dir_all(&crash_dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
}

/// The run-dir advisory lock: a second in-process acquisition (same live
/// pid) fails fast with guidance, and checkpoints without a run dir are
/// rejected up front.
#[test]
fn run_dir_lock_and_option_validation() {
    let dir = tmp_dir("locked");
    let _ = std::fs::remove_dir_all(&dir);
    let _held = schedule::RunDirLock::acquire(&dir).unwrap();
    let plan = one_slot_plan();
    let err = schedule::execute_plan(
        &plan,
        &ScheduleOptions { run_dir: Some(dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("locked by running process"), "{err}");
    drop(_held);
    let _ = std::fs::remove_dir_all(&dir);

    let err = schedule::execute_plan(
        &plan,
        &ScheduleOptions { checkpoint_every: 5, ..ScheduleOptions::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("run directory"), "{err}");
}
