//! Sequential-vs-threaded driver parity on the quadratic engine.
//!
//! Failure injection is a pure function of (seed, worker, round), so both
//! drivers must face the *identical* fault schedule: per-round sync counts
//! have to agree exactly. The numerics differ only through arrival order at
//! the master (that is the threaded driver's point), so the final accuracy
//! must agree statistically, not bitwise.

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::coordinator::{sim, FailureModel};
use deahes::strategies::Method;

fn parity_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 48, heterogeneity: 0.3, noise: 0.02 },
        workers: 3,
        tau: 2,
        rounds: 50,
        lr: 0.05,
        eval_subset: 8,
        eval_every: 1, // record every round so sync counts align 1:1
        failure: FailureModel::Burst { p_start: 0.2, mean_len: 5.0 },
        ..ExperimentConfig::default()
    }
}

fn run_both(cfg: &ExperimentConfig) -> (sim::RunResult, sim::RunResult) {
    let seq = sim::run(cfg).unwrap();
    let mut threaded = cfg.clone();
    threaded.threaded = true;
    let thr = sim::run(&threaded).unwrap();
    (seq, thr)
}

#[test]
fn per_round_sync_counts_are_identical_across_drivers() {
    let (seq, thr) = run_both(&parity_cfg());
    assert_eq!(seq.log.records.len(), thr.log.records.len());
    for (s, t) in seq.log.records.iter().zip(&thr.log.records) {
        assert_eq!(s.round, t.round);
        assert_eq!(
            (s.syncs_ok, s.syncs_failed),
            (t.syncs_ok, t.syncs_failed),
            "fault schedule diverged at round {}",
            s.round
        );
    }
    // the masters therefore served the same number of syncs per worker
    let served_seq: Vec<u64> = seq.worker_stats.iter().map(|s| s.0).collect();
    let served_thr: Vec<u64> = thr.worker_stats.iter().map(|s| s.0).collect();
    assert_eq!(served_seq, served_thr);
}

#[test]
fn final_accuracy_agrees_within_tolerance() {
    for method in [Method::DeahesO, Method::Easgd] {
        let mut cfg = parity_cfg();
        cfg.method = method;
        let (seq, thr) = run_both(&cfg);
        let a_seq = seq.log.tail_acc(10);
        let a_thr = thr.log.tail_acc(10);
        // Same config, same fault schedule, different arrival order: both
        // must land in the same converged neighbourhood.
        assert!(
            (a_seq - a_thr).abs() < 0.25,
            "{}: sequential tail acc {a_seq} vs threaded {a_thr}",
            method.name()
        );
        // and both actually converged (loss halved)
        for (name, r) in [("sequential", &seq), ("threaded", &thr)] {
            let first = r.log.records.first().unwrap().test_loss;
            let last = r.log.records.last().unwrap().test_loss;
            assert!(
                last < 0.5 * first,
                "{} {name}: loss {first} -> {last} did not halve",
                method.name()
            );
        }
    }
}
