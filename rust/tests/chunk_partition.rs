//! Partition-invariance properties for the parameter-chunked parallel tier.
//!
//! The determinism contract in `util::par` says a chunked kernel's result is
//! **bit-identical** for *any* chunk partition, including the scalar
//! one-chunk path. These properties attack that from two sides:
//!
//!   * the elastic sync kernels (`elastic_pull` / `elastic_absorb` /
//!     `elastic_step`) must commute with arbitrary block-aligned partitions —
//!     not just the uniform plans a [`Chunker`] produces;
//!   * the fused engine steps must produce the same bits under any thread
//!     count, in both noise regimes (the noisy path re-derives per-block RNG
//!     streams; the noise-free path is a plain vectorizable loop).
//!
//! With the `par` feature off, chunked dispatch runs the identical chunk
//! ranges sequentially, so these properties pin the same bits either way.

use deahes::engine::quad::QuadraticEngine;
use deahes::engine::{BatchRef, Engine, WorkerScratch};
use deahes::optim::native;
use deahes::util::par::{Chunker, NOISE_BLOCK};
use deahes::util::proptest;

fn empty() -> BatchRef<'static> {
    BatchRef { x: &[], y1h: &[] }
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit divergence at index {i}: {x} vs {y}");
    }
}

/// Random block-aligned cut points covering `0..n`: the partitions a chunked
/// call site could in principle be handed, a strict superset of the uniform
/// `(chunks, chunk_len)` plans `Chunker::plan` emits.
fn random_partition(g: &mut proptest::Gen, n: usize) -> Vec<(usize, usize)> {
    let mut cuts = Vec::new();
    let mut start = 0usize;
    while start < n {
        let blocks = 1 + g.rng().usize_below(4);
        let end = (start + blocks * NOISE_BLOCK).min(n);
        cuts.push((start, end));
        start = end;
    }
    cuts
}

#[test]
fn elastic_kernels_commute_with_any_block_partition() {
    proptest::check("elastic partition invariance", 120, |g| {
        let n = g.usize(1, 6000);
        let tw0 = g.vec_f32(n, -2.0, 2.0);
        let tm0 = g.vec_f32(n, -2.0, 2.0);
        let h1 = g.f32(0.0, 1.0);
        let h2 = g.f32(0.0, 1.0);

        // Whole-slice references.
        let mut pull_ref = tw0.clone();
        native::elastic_pull(&mut pull_ref, &tm0, h1);
        let mut absorb_ref = tm0.clone();
        native::elastic_absorb(&mut absorb_ref, &tw0, h2);
        let (mut step_w_ref, mut step_m_ref) = (tw0.clone(), tm0.clone());
        native::elastic_step(&mut step_w_ref, &mut step_m_ref, h1, h2);

        // (a) the scalar kernel applied per arbitrary block-aligned
        // sub-range matches the whole-slice call ...
        let parts = random_partition(g, n);
        let mut pull_parts = tw0.clone();
        let mut absorb_parts = tm0.clone();
        for &(s, e) in &parts {
            native::elastic_pull(&mut pull_parts[s..e], &tm0[s..e], h1);
            native::elastic_absorb(&mut absorb_parts[s..e], &tw0[s..e], h2);
        }
        assert_bits(&pull_ref, &pull_parts, "pull vs arbitrary partition");
        assert_bits(&absorb_ref, &absorb_parts, "absorb vs arbitrary partition");

        // ... and (b) the chunked dispatch wrappers match for any thread
        // count, including degenerate ones far above the block count.
        let threads = *g.pick(&[1usize, 2, 3, 5, 8, 64]);
        let ck = Chunker::new(threads);
        let mut pull_ck = tw0.clone();
        native::elastic_pull_chunked(&mut pull_ck, &tm0, h1, &ck);
        assert_bits(&pull_ref, &pull_ck, &format!("pull vs chunked t={threads}"));
        let mut absorb_ck = tm0.clone();
        native::elastic_absorb_chunked(&mut absorb_ck, &tw0, h2, &ck);
        assert_bits(&absorb_ref, &absorb_ck, &format!("absorb vs chunked t={threads}"));
        let (mut step_w_ck, mut step_m_ck) = (tw0.clone(), tm0.clone());
        native::elastic_step_chunked(&mut step_w_ck, &mut step_m_ck, h1, h2, &ck);
        assert_bits(&step_w_ref, &step_w_ck, &format!("step θw vs chunked t={threads}"));
        assert_bits(&step_m_ref, &step_m_ck, &format!("step θm vs chunked t={threads}"));
    });
}

#[test]
fn fused_steps_are_partition_invariant_in_both_noise_regimes() {
    proptest::check("fused step partition invariance", 40, |g| {
        let n = g.usize(1, 5000);
        let noise = *g.pick(&[0.0f32, 0.05]);
        let threads = *g.pick(&[2usize, 3, 5, 8]);
        let seed = g.u64();
        let lr = g.f32(0.005, 0.05);
        let theta0 = g.vec_f32(n, -1.0, 1.0);
        // Identical probe draws for both trajectories (AdaHessian).
        let probe_seed = g.u64();

        let mut scalar = QuadraticEngine::new(n, seed, 1, 0.3, noise);
        let mut chunked = QuadraticEngine::new(n, seed, 1, 0.3, noise);
        chunked.set_intra_parallel(threads);

        let mut theta_s = theta0.clone();
        let mut theta_c = theta0;
        let (mut m_s, mut v_s) = (vec![0.0f32; n], vec![0.0f32; n]);
        let (mut m_c, mut v_c) = (vec![0.0f32; n], vec![0.0f32; n]);
        let mut probe_s = deahes::util::rng::Rng::new(probe_seed);
        let mut probe_c = deahes::util::rng::Rng::new(probe_seed);
        let mut scratch = WorkerScratch::new(n);

        for t in 1..=3u64 {
            // Alternate optimizers so both the single-noise-pass kernel
            // (sgd) and the double-pass kernel (adahessian: grad key then
            // diag key) are exercised on the same engine stream.
            let (ls, lc) = if t % 2 == 1 {
                let ls = scalar.sgd_step(&mut theta_s, empty(), lr, &mut scratch).unwrap();
                let lc = chunked.sgd_step(&mut theta_c, empty(), lr, &mut scratch).unwrap();
                (ls, lc)
            } else {
                let zs = probe_s.rademacher(n);
                let zc = probe_c.rademacher(n);
                let ls = scalar
                    .adahessian_step(
                        &mut theta_s,
                        empty(),
                        &zs,
                        &mut m_s,
                        &mut v_s,
                        t,
                        lr,
                        &mut scratch,
                    )
                    .unwrap();
                let lc = chunked
                    .adahessian_step(
                        &mut theta_c,
                        empty(),
                        &zc,
                        &mut m_c,
                        &mut v_c,
                        t,
                        lr,
                        &mut scratch,
                    )
                    .unwrap();
                (ls, lc)
            };
            assert_eq!(
                ls.to_bits(),
                lc.to_bits(),
                "loss bits, n={n} noise={noise} threads={threads} t={t}"
            );
            assert_bits(
                &theta_s,
                &theta_c,
                &format!("theta, n={n} noise={noise} threads={threads} t={t}"),
            );
        }
        assert_bits(&m_s, &m_c, "adahessian m");
        assert_bits(&v_s, &v_c, "adahessian v");
    });
}
