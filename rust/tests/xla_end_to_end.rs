//! Integration: short REAL runs through the full stack (artifacts + PJRT +
//! coordinator), both drivers. Small round counts keep this in CI budget;
//! the long-horizon run lives in examples/e2e_train.rs.

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::coordinator::{sim, FailureModel};
use deahes::strategies::Method;

fn xla_cfg() -> Option<ExperimentConfig> {
    if !std::path::Path::new("artifacts/metadata.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(ExperimentConfig {
        engine: EngineKind::Xla { artifacts_dir: "artifacts".into(), native_opt: false },
        workers: 2,
        tau: 1,
        rounds: 6,
        lr: 0.05,
        train_size: 512,
        test_size: 256,
        eval_subset: 512, // one eval batch
        eval_every: 2,
        ..ExperimentConfig::default()
    })
}

#[test]
fn sequential_real_run_produces_finite_metrics() {
    let Some(mut cfg) = xla_cfg() else { return };
    cfg.method = Method::DeahesO;
    let r = sim::run(&cfg).unwrap();
    assert!(!r.log.records.is_empty());
    for rec in &r.log.records {
        assert!(rec.test_acc.is_finite() && (0.0..=1.0).contains(&rec.test_acc));
        assert!(rec.train_loss.is_finite() && rec.train_loss > 0.0);
    }
    // paper's failure model: some syncs should have been suppressed
    let failed: u32 = r.log.records.iter().map(|x| x.syncs_failed).sum();
    let ok: u32 = r.log.records.iter().map(|x| x.syncs_ok).sum();
    assert!(ok > 0, "no successful syncs at all");
    let _ = failed; // 6 rounds x 2 workers: suppression is possible but not guaranteed
}

#[test]
fn sequential_real_run_is_deterministic() {
    let Some(mut cfg) = xla_cfg() else { return };
    cfg.method = Method::Eahes;
    cfg.rounds = 4;
    let a = sim::run(&cfg).unwrap();
    let b = sim::run(&cfg).unwrap();
    for (x, y) in a.log.records.iter().zip(&b.log.records) {
        assert!(
            (x.train_loss - y.train_loss).abs() < 1e-6,
            "round {}: {} vs {}",
            x.round,
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.test_acc, y.test_acc);
    }
}

#[test]
fn threaded_real_run_completes_with_per_thread_clients() {
    let Some(mut cfg) = xla_cfg() else { return };
    cfg.method = Method::DeahesO;
    cfg.threaded = true;
    cfg.rounds = 3;
    let r = sim::run(&cfg).unwrap();
    assert_eq!(r.log.records.last().unwrap().round, 2);
    // both worker engines + master engine reported call stats
    assert!(r.perf.contains("grad_hess"), "worker engine stats missing");
    assert!(r.perf.contains("elastic"), "master engine stats missing");
}

#[test]
fn sgd_family_methods_run_on_artifacts() {
    let Some(mut cfg) = xla_cfg() else { return };
    cfg.rounds = 3;
    for m in [Method::Easgd, Method::Eamsgd] {
        cfg.method = m;
        let r = sim::run(&cfg).unwrap();
        assert!(r.log.records.last().unwrap().train_loss.is_finite(), "{}", m.name());
    }
}

#[test]
fn paper_ordering_under_burst_failures() {
    // The §VII headline on the REAL engine: under node-down burst outages,
    // the oracle and the dynamic policy must beat fixed α. (Under the
    // paper's milder iid-1/3 model the gaps are within seed noise at CI
    // horizons — see EXPERIMENTS.md; bursts make the staleness effect
    // unambiguous at 60 rounds.)
    let Some(mut cfg) = xla_cfg() else { return };
    cfg.workers = 4;
    cfg.tau = 2;
    cfg.rounds = 80;
    cfg.lr = 0.1;
    cfg.train_size = 8192;
    cfg.test_size = 2048;
    cfg.overlap_ratio = 0.25;
    cfg.eval_every = 5;
    cfg.failure = FailureModel::Burst { p_start: 0.12, mean_len: 8.0 };
    let run_m = |method: Method, cfg: &ExperimentConfig| {
        let mut c = cfg.clone();
        c.method = method;
        sim::run(&c).unwrap().log.tail_train_loss(4)
    };
    let fixed = run_m(Method::EahesO, &cfg);
    let dynamic = run_m(Method::DeahesO, &cfg);
    let oracle = run_m(Method::EahesOm, &cfg);
    // Shape claim with slack for single-seed noise: mitigation must not be
    // worse than fixed α (at this calibrated config it is measurably
    // better: ~0.28/0.30 vs ~0.49 train loss — EXPERIMENTS.md §Ordering).
    assert!(
        dynamic <= fixed * 1.10,
        "DEAHES-O train loss {dynamic} worse than EAHES-O {fixed}"
    );
    assert!(
        oracle <= fixed * 1.10,
        "EAHES-OM train loss {oracle} worse than EAHES-O {fixed}"
    );
}

#[test]
fn failure_free_run_has_no_suppressed_syncs() {
    let Some(mut cfg) = xla_cfg() else { return };
    cfg.method = Method::EahesO;
    cfg.failure = FailureModel::None;
    cfg.rounds = 3;
    let r = sim::run(&cfg).unwrap();
    for rec in &r.log.records {
        assert_eq!(rec.syncs_failed, 0);
        assert_eq!(rec.syncs_ok, cfg.workers as u32);
    }
}
