//! Equivalence regression for the zero-allocation hot-path redesign.
//!
//! The fused engine steps (`sgd_step`, `momentum_step`, `adahessian_step`)
//! and the new fused kernels (`adamw_step`, `elastic_pull`) must be
//! pointwise **bit-identical** to the pre-change multi-pass compositions
//! (gradient into a buffer, then the separate update kernel). Two engines
//! constructed from the same seed share identical RNG streams, so running
//! one through the fused path and one through the composed path and
//! comparing every parameter bit after every step pins the contract the
//! schedule-determinism and driver-parity suites rely on.

use deahes::engine::quad::QuadraticEngine;
use deahes::engine::{BatchRef, Engine, WorkerScratch};
use deahes::optim::native;
use deahes::util::rng::Rng;

fn empty() -> BatchRef<'static> {
    BatchRef { x: &[], y1h: &[] }
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit divergence at index {i}: {x} vs {y}");
    }
}

/// Engines with noise exercise the RNG-ordering half of the contract;
/// noise-free engines exercise the vectorizable fast path. Test both.
const NOISES: [f32; 2] = [0.0, 0.05];

#[test]
fn fused_sgd_step_is_bit_identical_to_grad_plus_sgd() {
    for noise in NOISES {
        let n = 96;
        let mut fused = QuadraticEngine::new(n, 41, 1, 0.3, noise);
        let mut composed = QuadraticEngine::new(n, 41, 1, 0.3, noise);
        let mut theta_f = vec![0.7f32; n];
        let mut theta_c = vec![0.7f32; n];
        let mut scratch = WorkerScratch::new(n);
        let mut g = vec![0.0f32; n];
        for step in 0..50 {
            let lf = fused.sgd_step(&mut theta_f, empty(), 0.03, &mut scratch).unwrap();
            let lc = composed.grad(&theta_c, empty(), &mut g).unwrap();
            composed.sgd(&mut theta_c, &g, 0.03).unwrap();
            assert_eq!(lf.to_bits(), lc.to_bits(), "loss bits, noise={noise}, step {step}");
            assert_bits(&theta_f, &theta_c, &format!("sgd theta, noise={noise}, step {step}"));
        }
    }
}

#[test]
fn fused_momentum_step_is_bit_identical_to_grad_plus_momentum() {
    for noise in NOISES {
        let n = 64;
        let mut fused = QuadraticEngine::new(n, 42, 2, 0.3, noise);
        let mut composed = QuadraticEngine::new(n, 42, 2, 0.3, noise);
        let mut theta_f = vec![-0.4f32; n];
        let mut theta_c = vec![-0.4f32; n];
        let mut buf_f = vec![0.0f32; n];
        let mut buf_c = vec![0.0f32; n];
        let mut scratch = WorkerScratch::new(n);
        let mut g = vec![0.0f32; n];
        for step in 0..50 {
            let lf = fused
                .momentum_step(&mut theta_f, empty(), &mut buf_f, 0.02, &mut scratch)
                .unwrap();
            let lc = composed.grad(&theta_c, empty(), &mut g).unwrap();
            composed.momentum(&mut theta_c, &g, &mut buf_c, 0.02).unwrap();
            assert_eq!(lf.to_bits(), lc.to_bits(), "loss bits, noise={noise}, step {step}");
            assert_bits(&theta_f, &theta_c, &format!("momentum theta, noise={noise}"));
            assert_bits(&buf_f, &buf_c, &format!("momentum buf, noise={noise}"));
        }
    }
}

#[test]
fn fused_adahessian_step_is_bit_identical_to_grad_hess_plus_adahessian() {
    for noise in NOISES {
        let n = 64;
        let mut fused = QuadraticEngine::new(n, 43, 3, 0.3, noise);
        let mut composed = QuadraticEngine::new(n, 43, 3, 0.3, noise);
        let mut theta_f = vec![0.9f32; n];
        let mut theta_c = vec![0.9f32; n];
        let (mut mf, mut vf) = (vec![0.0f32; n], vec![0.0f32; n]);
        let (mut mc, mut vc) = (vec![0.0f32; n], vec![0.0f32; n]);
        let mut scratch = WorkerScratch::new(n);
        let mut g = vec![0.0f32; n];
        let mut d = vec![0.0f32; n];
        // identical probe streams for both paths
        let mut probe_f = Rng::new(99);
        let mut probe_c = Rng::new(99);
        for t in 1..=40 {
            let zf = probe_f.rademacher(n);
            let zc = probe_c.rademacher(n);
            let lf = fused
                .adahessian_step(
                    &mut theta_f,
                    empty(),
                    &zf,
                    &mut mf,
                    &mut vf,
                    t,
                    0.02,
                    &mut scratch,
                )
                .unwrap();
            let lc = composed.grad_hess(&theta_c, empty(), &zc, &mut g, &mut d).unwrap();
            composed.adahessian(&mut theta_c, &g, &d, &mut mc, &mut vc, t, 0.02).unwrap();
            assert_eq!(lf.to_bits(), lc.to_bits(), "loss bits, noise={noise}, t={t}");
            assert_bits(&theta_f, &theta_c, &format!("ada theta, noise={noise}"));
            assert_bits(&mf, &mc, "ada m");
            assert_bits(&vf, &vc, "ada v");
        }
    }
}

/// The fused AdamW kernel against an explicit three-pass reference
/// (moment pass, variance pass, parameter pass over separate loops).
#[test]
fn fused_adamw_matches_three_pass_reference() {
    let n = 128;
    let (beta1, beta2, eps, wd, lr) = (0.9f32, 0.999f32, 1e-8f32, 0.01f32, 0.05f32);
    let mut rng = Rng::new(5);
    let mut theta_a: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut theta_b = theta_a.clone();
    let (mut ma, mut va) = (vec![0.0f32; n], vec![0.0f32; n]);
    let (mut mb, mut vb) = (vec![0.0f32; n], vec![0.0f32; n]);
    for t in 1..=30 {
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        native::adamw_step(&mut theta_a, &g, &mut ma, &mut va, t, lr, beta1, beta2, eps, wd);
        // three-pass reference
        let bc1 = 1.0 - beta1.powi(t as i32);
        let bc2 = 1.0 - beta2.powi(t as i32);
        for i in 0..n {
            mb[i] = beta1 * mb[i] + (1.0 - beta1) * g[i];
        }
        for i in 0..n {
            vb[i] = beta2 * vb[i] + (1.0 - beta2) * g[i] * g[i];
        }
        for i in 0..n {
            let mh = mb[i] / bc1;
            let vh = vb[i] / bc2;
            theta_b[i] -= lr * (mh / (vh.sqrt() + eps) + wd * theta_b[i]);
        }
        assert_bits(&theta_a, &theta_b, "adamw theta");
        assert_bits(&ma, &mb, "adamw m");
        assert_bits(&va, &vb, "adamw v");
    }
}

/// `elastic_pull` is exactly the worker half of the pair update, and the
/// pair update through the engine matches the native kernel.
#[test]
fn elastic_pull_matches_pair_update_worker_side() {
    let n = 77;
    let mut rng = Rng::new(6);
    let tw0: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    let tm0: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    for h1 in [0.0f32, 0.1, 0.5, 1.0] {
        let mut pair_w = tw0.clone();
        let mut pair_m = tm0.clone();
        native::elastic_step(&mut pair_w, &mut pair_m, h1, 0.1);
        let mut pull_w = tw0.clone();
        native::elastic_pull(&mut pull_w, &tm0, h1);
        assert_bits(&pair_w, &pull_w, &format!("elastic h1={h1}"));
        // and through the engine trait
        let mut e = QuadraticEngine::new(n, 7, 0, 0.0, 0.0);
        let mut ew = tw0.clone();
        let mut em = tm0.clone();
        e.elastic(&mut ew, &mut em, h1, 0.1).unwrap();
        assert_bits(&ew, &pair_w, "engine elastic tw");
        assert_bits(&em, &pair_m, "engine elastic tm");
    }
}

/// `elastic_absorb` is exactly the master half of the pair update — the
/// gossip-mode fold kernel (`MasterState::absorb_gossip`) splits eq. 13
/// from the pair exactly like `elastic_pull` splits eq. 12.
#[test]
fn elastic_absorb_matches_pair_update_master_side() {
    let n = 77;
    let mut rng = Rng::new(8);
    let tw0: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    let tm0: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
    for h2 in [0.0f32, 0.1, 0.5, 1.0] {
        let mut pair_w = tw0.clone();
        let mut pair_m = tm0.clone();
        native::elastic_step(&mut pair_w, &mut pair_m, 0.3, h2);
        let mut absorb_m = tm0.clone();
        native::elastic_absorb(&mut absorb_m, &tw0, h2);
        assert_bits(&pair_m, &absorb_m, &format!("absorb h2={h2}"));
    }
}

/// The fused AdamW training path (`WorkerState::local_round` over an AdamW
/// `OptState`, stepping through `Engine::adamw_step` and the scratch arena)
/// is bit-identical to a whole-round manual emulation: per step, a gradient
/// pass into a buffer followed by three separate m/v/θ passes. This is the
/// preset-level mirror of `fused_adamw_matches_three_pass_reference` — it
/// pins the kernel AND all the plumbing (OptState params, per-step `t`,
/// spec-pinned lr) between the driver and the kernel.
#[test]
fn adamw_preset_round_is_bit_identical_to_three_pass_emulation() {
    use deahes::coordinator::worker::WorkerState;
    use deahes::elastic::score::geometric_weights;
    use deahes::optim::OptimSpec;

    let n = 48;
    let tau = 3;
    let spec =
        OptimSpec::parse("adamw(lr=0.02,beta1=0.9,beta2=0.999,eps=0.00000001,wd=0.01)").unwrap();
    // Derive the emulation's f32 constants from the parsed spec exactly as
    // the worker does, so the comparison can only diverge through the
    // update path itself.
    let OptimSpec::AdamW(params) = spec else { unreachable!() };
    let lr = params.lr.unwrap() as f32;
    let (beta1, beta2) = (params.beta1 as f32, params.beta2 as f32);
    let (eps, wd) = (params.eps as f32, params.wd as f32);
    for noise in NOISES {
        let mut engine_f = QuadraticEngine::new(n, 45, 1, 0.2, noise);
        let mut engine_c = QuadraticEngine::new(n, 45, 1, 0.2, noise);
        let mut ws = WorkerState::new(
            0,
            vec![0.25; n],
            spec.state(n),
            0.05, // run-level lr — must be shadowed by the spec's lr=0.02
            None,
            geometric_weights(4, 0.5),
            Rng::new(9),
        );
        let mut theta_c = vec![0.25f32; n];
        let (mut mc, mut vc) = (vec![0.0f32; n], vec![0.0f32; n]);
        let mut g = vec![0.0f32; n];
        let mut t = 0u64;
        for round in 0..10 {
            let loss_f = ws.local_round(&mut engine_f, tau).unwrap();
            let mut loss_sum = 0.0f32;
            for _ in 0..tau {
                t += 1;
                loss_sum += engine_c.grad(&theta_c, empty(), &mut g).unwrap();
                // three-pass reference
                let bc1 = 1.0 - beta1.powi(t as i32);
                let bc2 = 1.0 - beta2.powi(t as i32);
                for i in 0..n {
                    mc[i] = beta1 * mc[i] + (1.0 - beta1) * g[i];
                }
                for i in 0..n {
                    vc[i] = beta2 * vc[i] + (1.0 - beta2) * g[i] * g[i];
                }
                for i in 0..n {
                    let mh = mc[i] / bc1;
                    let vh = vc[i] / bc2;
                    theta_c[i] -= lr * (mh / (vh.sqrt() + eps) + wd * theta_c[i]);
                }
            }
            let loss_c = loss_sum / tau as f32;
            assert_eq!(
                loss_f.to_bits(),
                loss_c.to_bits(),
                "round {round} loss, noise={noise}"
            );
            assert_bits(&ws.theta, &theta_c, &format!("round {round} theta, noise={noise}"));
        }
    }
}

/// The parameter-chunked parallel tier must be bit-identical to the scalar
/// tier for **every** fused optimizer step, any thread count, both noise
/// regimes, and dimensions that do / don't divide evenly into noise blocks.
/// This is the core determinism contract of `util::par`: a chunked engine
/// (`set_intra_parallel`) re-derives each block's noise stream from the same
/// per-pass key as the scalar engine, and the block-ordered loss fold makes
/// the f32 accumulation sequence partition-independent. With the `par`
/// feature off the dispatch degenerates to a sequential loop over the same
/// chunk ranges, so this test pins the same bits either way.
#[test]
fn chunked_fused_steps_are_bit_identical_to_scalar_for_all_optimizers() {
    // 3000 is not a multiple of NOISE_BLOCK (tail block), 4096 is several
    // whole blocks; both must chunk cleanly.
    for n in [3000usize, 4096] {
        for noise in NOISES {
            // Scalar reference trajectories, one per optimizer.
            let mut scalar = Trajectories::new(n, noise, 0);
            for step in 1..=4 {
                scalar.step(step);
            }
            for threads in [1usize, 2, 3, 5, 8] {
                let mut chunked = Trajectories::new(n, noise, threads);
                for step in 1..=4 {
                    chunked.step(step);
                }
                let what = format!("n={n} noise={noise} threads={threads}");
                assert_eq!(
                    scalar.loss_bits, chunked.loss_bits,
                    "loss bit divergence, {what}"
                );
                assert_bits(&scalar.sgd_theta, &chunked.sgd_theta, &format!("sgd {what}"));
                assert_bits(&scalar.mom_theta, &chunked.mom_theta, &format!("mom θ {what}"));
                assert_bits(&scalar.mom_buf, &chunked.mom_buf, &format!("mom buf {what}"));
                assert_bits(&scalar.ada_theta, &chunked.ada_theta, &format!("ada θ {what}"));
                assert_bits(&scalar.ada_m, &chunked.ada_m, &format!("ada m {what}"));
                assert_bits(&scalar.ada_v, &chunked.ada_v, &format!("ada v {what}"));
                assert_bits(&scalar.adamw_theta, &chunked.adamw_theta, &format!("adamw θ {what}"));
                assert_bits(&scalar.adamw_m, &chunked.adamw_m, &format!("adamw m {what}"));
                assert_bits(&scalar.adamw_v, &chunked.adamw_v, &format!("adamw v {what}"));
            }
        }
    }

    /// One engine + parameter/state vectors per fused optimizer, all
    /// advanced in lock-step so a single pass covers the whole kernel set.
    struct Trajectories {
        sgd_e: QuadraticEngine,
        mom_e: QuadraticEngine,
        ada_e: QuadraticEngine,
        adamw_e: QuadraticEngine,
        sgd_theta: Vec<f32>,
        mom_theta: Vec<f32>,
        mom_buf: Vec<f32>,
        ada_theta: Vec<f32>,
        ada_m: Vec<f32>,
        ada_v: Vec<f32>,
        adamw_theta: Vec<f32>,
        adamw_m: Vec<f32>,
        adamw_v: Vec<f32>,
        probe: Rng,
        scratch: WorkerScratch,
        /// Sum of all loss bit patterns (wrapping) — a cheap order-sensitive
        /// digest of every per-step loss across the run.
        loss_bits: u64,
    }

    impl Trajectories {
        fn new(n: usize, noise: f32, threads: usize) -> Trajectories {
            let mk = |seed: u64| {
                let mut e = QuadraticEngine::new(n, seed, 1, 0.3, noise);
                if threads > 0 {
                    e.set_intra_parallel(threads);
                }
                e
            };
            Trajectories {
                sgd_e: mk(71),
                mom_e: mk(72),
                ada_e: mk(73),
                adamw_e: mk(74),
                sgd_theta: vec![0.6; n],
                mom_theta: vec![-0.4; n],
                mom_buf: vec![0.0; n],
                ada_theta: vec![0.9; n],
                ada_m: vec![0.0; n],
                ada_v: vec![0.0; n],
                adamw_theta: vec![0.25; n],
                adamw_m: vec![0.0; n],
                adamw_v: vec![0.0; n],
                probe: Rng::new(75),
                scratch: WorkerScratch::new(n),
                loss_bits: 0,
            }
        }

        fn step(&mut self, t: u64) {
            let n = self.sgd_theta.len();
            let mut losses = [0.0f32; 4];
            losses[0] = self
                .sgd_e
                .sgd_step(&mut self.sgd_theta, empty(), 0.03, &mut self.scratch)
                .unwrap();
            losses[1] = self
                .mom_e
                .momentum_step(
                    &mut self.mom_theta,
                    empty(),
                    &mut self.mom_buf,
                    0.02,
                    &mut self.scratch,
                )
                .unwrap();
            let z = self.probe.rademacher(n);
            losses[2] = self
                .ada_e
                .adahessian_step(
                    &mut self.ada_theta,
                    empty(),
                    &z,
                    &mut self.ada_m,
                    &mut self.ada_v,
                    t,
                    0.02,
                    &mut self.scratch,
                )
                .unwrap();
            losses[3] = self
                .adamw_e
                .adamw_step(
                    &mut self.adamw_theta,
                    empty(),
                    &mut self.adamw_m,
                    &mut self.adamw_v,
                    t,
                    0.02,
                    0.9,
                    0.999,
                    1e-8,
                    0.01,
                    &mut self.scratch,
                )
                .unwrap();
            for l in losses {
                self.loss_bits = self.loss_bits.wrapping_add(l.to_bits() as u64);
            }
        }
    }
}

/// A full worker-state round through the fused path matches a manual
/// composed emulation bit-for-bit — the whole-round contract the drivers
/// depend on.
#[test]
fn worker_round_is_bit_identical_to_composed_emulation() {
    use deahes::coordinator::worker::WorkerState;
    use deahes::elastic::score::geometric_weights;
    use deahes::optim::OptState;
    use deahes::optim::Optimizer;

    let n = 48;
    let tau = 3;
    let mut engine_f = QuadraticEngine::new(n, 44, 1, 0.2, 0.05);
    let mut engine_c = QuadraticEngine::new(n, 44, 1, 0.2, 0.05);
    let mut ws = WorkerState::new(
        0,
        vec![0.25; n],
        OptState::new(Optimizer::Sgd, n),
        0.05,
        None,
        geometric_weights(4, 0.5),
        Rng::new(9),
    );
    let mut theta_c = vec![0.25f32; n];
    let mut g = vec![0.0f32; n];
    for round in 0..10 {
        let loss_f = ws.local_round(&mut engine_f, tau).unwrap();
        let mut loss_sum = 0.0f32;
        for _ in 0..tau {
            loss_sum += engine_c.grad(&theta_c, empty(), &mut g).unwrap();
            engine_c.sgd(&mut theta_c, &g, 0.05).unwrap();
        }
        let loss_c = loss_sum / tau as f32;
        assert_eq!(loss_f.to_bits(), loss_c.to_bits(), "round {round} loss");
        assert_bits(&ws.theta, &theta_c, &format!("round {round} theta"));
    }
}
