//! Integration: backend-invariance and resume of the trial-schedule engine.
//!
//! The contract under test (docs/ARCHITECTURE.md):
//!  * a plan executed through the sequential backend and through the
//!    thread-pool backend commits byte-identical JSONL records and produces
//!    identical averaged series (wall-clock aside);
//!  * a sweep killed after committing some trials resumes without
//!    re-running them.

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::experiments;
use deahes::schedule::{self, ScheduleOptions, TrialPlan};
use deahes::strategies::Method;
use std::path::{Path, PathBuf};

fn quad_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 32, heterogeneity: 0.2, noise: 0.02 },
        workers: 3,
        tau: 2,
        rounds: 10,
        eval_subset: 16,
        ..ExperimentConfig::default()
    }
}

/// 2 methods × 2 seeds, the sweep shape from the issue's acceptance check.
fn small_grid_plan() -> TrialPlan {
    let mut plan = TrialPlan::new();
    for m in [Method::Easgd, Method::DeahesO] {
        let mut cfg = quad_cfg();
        cfg.method = m;
        cfg.overlap_ratio = m.paper_overlap_ratio(cfg.workers);
        plan.push_cell(&format!("det/{}", m.name()), m.name(), &cfg, 2);
    }
    plan
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deahes-determinism-{}-{name}", std::process::id()))
}

fn runs_file(dir: &Path) -> PathBuf {
    dir.join(schedule::RUNS_FILE)
}

#[test]
fn backends_commit_byte_identical_jsonl_and_series() {
    let seq_dir = tmp_dir("seq");
    let pool_dir = tmp_dir("pool");
    let _ = std::fs::remove_dir_all(&seq_dir);
    let _ = std::fs::remove_dir_all(&pool_dir);

    let plan = small_grid_plan();
    let seq = schedule::execute_plan(
        &plan,
        &ScheduleOptions { jobs: 1, run_dir: Some(seq_dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    let pool = schedule::execute_plan(
        &plan,
        &ScheduleOptions { jobs: 4, run_dir: Some(pool_dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    assert_eq!(seq.backend, "sequential");
    assert_eq!(pool.backend, "thread-pool");

    // the committed JSONL must be byte-identical
    let seq_bytes = std::fs::read(runs_file(&seq_dir)).unwrap();
    let pool_bytes = std::fs::read(runs_file(&pool_dir)).unwrap();
    assert!(!seq_bytes.is_empty());
    assert_eq!(seq_bytes, pool_bytes, "run sinks differ between backends");

    // and so must the averaged series built from the outcomes
    let a = experiments::series_by_cell(&plan, &seq.outcomes);
    let b = experiments::series_by_cell(&plan, &pool.outcomes);
    assert_eq!(a.len(), 2);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.deterministic_digest(), y.deterministic_digest(), "{}", x.label);
    }

    let _ = std::fs::remove_dir_all(&seq_dir);
    let _ = std::fs::remove_dir_all(&pool_dir);
}

#[test]
fn killed_sweep_resumes_without_rerunning_committed_trials() {
    let dir = tmp_dir("resume");
    let _ = std::fs::remove_dir_all(&dir);

    // "kill" a sweep after its first cell: run a prefix of the plan
    let mut prefix = TrialPlan::new();
    {
        let mut cfg = quad_cfg();
        cfg.method = Method::Easgd;
        cfg.overlap_ratio = Method::Easgd.paper_overlap_ratio(cfg.workers);
        prefix.push_cell(&format!("det/{}", Method::Easgd.name()), Method::Easgd.name(), &cfg, 2);
    }
    let opts =
        ScheduleOptions { jobs: 1, run_dir: Some(dir.clone()), ..ScheduleOptions::default() };
    let first = schedule::execute_plan(&prefix, &opts).unwrap();
    assert_eq!(first.executed, 2);

    // resume the FULL plan: the prefix cell must come from the sink
    let plan = small_grid_plan();
    let opts = ScheduleOptions { resume: true, ..opts };
    let resumed = schedule::execute_plan(&plan, &opts).unwrap();
    assert_eq!(resumed.skipped, 2, "committed trials must not re-run");
    assert_eq!(resumed.executed, 2);
    assert!(resumed.outcomes[0].cached && resumed.outcomes[1].cached);
    assert!(!resumed.outcomes[2].cached && !resumed.outcomes[3].cached);

    // a fresh uninterrupted run agrees with the resumed one exactly
    let fresh_dir = tmp_dir("fresh");
    let _ = std::fs::remove_dir_all(&fresh_dir);
    let fresh = schedule::execute_plan(
        &plan,
        &ScheduleOptions {
            jobs: 1,
            run_dir: Some(fresh_dir.clone()),
            ..ScheduleOptions::default()
        },
    )
    .unwrap();
    assert_eq!(
        std::fs::read(runs_file(&dir)).unwrap(),
        std::fs::read(runs_file(&fresh_dir)).unwrap(),
        "resumed sink must match an uninterrupted run byte-for-byte"
    );
    for (x, y) in experiments::series_by_cell(&plan, &resumed.outcomes)
        .iter()
        .zip(&experiments::series_by_cell(&plan, &fresh.outcomes))
    {
        assert_eq!(x.deterministic_digest(), y.deterministic_digest());
    }

    // a second resume of a complete sweep runs nothing at all
    let again = schedule::execute_plan(&plan, &opts).unwrap();
    assert_eq!(again.executed, 0);
    assert_eq!(again.skipped, 4);

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&fresh_dir);
}
