//! The fault-scenario subsystem end to end (ISSUE 8 acceptance):
//!
//!  1. trace-driven replay — a generative model's realized schedule,
//!     recorded to a `deahes-trace/v1` file, replays byte-identically
//!     under `--failure trace:PATH` across policies and drivers (the
//!     shared `fault_digest` proves the pairing);
//!  2. heterogeneous stragglers — per-worker `speeds` produce nonuniform
//!     sync participation and wait behaviour with NO kills, and the
//!     staleness-aware policies (`delayed`, `adaptive`) measurably
//!     respond where `fixed` cannot;
//!  3. elastic membership — workers leave and rejoin mid-run, and
//!     checkpoint/resume across the transitions stays byte-identical.
//!
//! Byte-identity is asserted within a driver: the threaded drivers agree
//! with sequential on every schedule-level fact (fault schedule, sync
//! counts, served totals) but intentionally differ in arrival order at
//! the master (see tests/driver_parity.rs).

use deahes::config::{EngineKind, ExperimentConfig, SyncMode};
use deahes::coordinator::checkpoint::RunCheckpoint;
use deahes::coordinator::sim::{self, CheckpointHooks};
use deahes::coordinator::{FailureModel, TraceFile};
use deahes::strategies::Method;
use deahes::util::json::Json;
use std::path::PathBuf;

fn quad_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 32, heterogeneity: 0.3, noise: 0.05 },
        method: Method::DeahesO,
        workers: 3,
        tau: 2,
        rounds: 24,
        eval_subset: 16,
        eval_every: 1,
        failure: FailureModel::Burst { p_start: 0.25, mean_len: 4.0 },
        ..ExperimentConfig::default()
    }
}

/// The deterministic content a committed record would carry, plus the
/// realized-schedule digest.
fn digest(r: &sim::RunResult) -> String {
    let mut log = r.log.clone();
    log.canonicalize_non_finite();
    Json::obj(vec![
        ("records", log.to_json()),
        ("sim", r.sim.to_json()),
        ("worker_stats", Json::arr_u64_pairs(&r.worker_stats)),
        ("fault_digest", Json::str(&deahes::util::bits::u64_hex(r.fault_digest))),
    ])
    .to_string_compact()
}

fn tmp_trace(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("deahes-scenario-{}-{name}.trace.json", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Trace files round-trip bit-exactly through disk, and the digest guards
/// against corruption.
#[test]
fn trace_file_roundtrips_and_detects_corruption() {
    let cfg = quad_cfg();
    let trace =
        TraceFile::capture(&cfg.failure, cfg.seed, cfg.workers, cfg.rounds).unwrap();
    let path = tmp_trace("roundtrip");
    trace.save(&path).unwrap();
    let back = TraceFile::load(&path).unwrap();
    assert_eq!(back, trace, "trace file must round-trip bit-exactly");
    assert_eq!(back.table.digest(), trace.table.digest());

    // flip one suppression bit in the JSON: the digest check must catch it
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap();
    let first = j.get("suppressed").as_arr().unwrap()[0].as_str().unwrap();
    let mut chars: Vec<char> = first.chars().collect();
    chars[0] = if chars[0] == '0' { '1' } else { '0' };
    let flipped: String = chars.into_iter().collect();
    let corrupted = text.replacen(first, &flipped, 1);
    std::fs::write(&path, corrupted).unwrap();
    let err = format!("{:#}", TraceFile::load(&path).unwrap_err());
    assert!(err.contains("digest mismatch"), "{err}");
    let _ = std::fs::remove_file(&path);
}

/// The headline acceptance pin: a recorded burst schedule replays
/// byte-identically under 2 policies and both drivers (and in gossip
/// mode), with the same `fault_digest` everywhere.
#[test]
fn recorded_trace_replays_byte_identically_across_policies_and_drivers() {
    let base = quad_cfg();
    let trace =
        TraceFile::capture(&base.failure, base.seed, base.workers, base.rounds).unwrap();
    let path = tmp_trace("replay");
    trace.save(&path).unwrap();
    let expect = trace.table.digest();

    for policy in ["fixed(alpha=0.1)", "delayed(alpha=0.1,staleness_cap=3)"] {
        for (sync_mode, threaded) in [
            (SyncMode::Central, false),
            (SyncMode::Central, true),
            (SyncMode::Gossip, false),
            (SyncMode::Gossip, true),
        ] {
            let mut burst_cfg = base.clone();
            burst_cfg.policy = Some(policy.to_string());
            burst_cfg.sync_mode = sync_mode;
            burst_cfg.threaded = threaded;
            let reference = sim::run(&burst_cfg).unwrap();
            assert_eq!(
                reference.fault_digest, expect,
                "{policy} {sync_mode:?} threaded={threaded}: burst digest mismatch"
            );
            let mut replay_cfg = burst_cfg.clone();
            replay_cfg.failure = FailureModel::Trace { path: path.clone() };
            let replayed = sim::run(&replay_cfg).unwrap();
            assert_eq!(
                replayed.fault_digest, expect,
                "{policy} {sync_mode:?} threaded={threaded}: replay digest mismatch"
            );
            if threaded {
                // schedule-level facts are driver-invariant; numerics are
                // arrival-order dependent, so byte-compare is sequential-only
                assert_eq!(reference.log.records.len(), replayed.log.records.len());
                for (a, b) in reference.log.records.iter().zip(&replayed.log.records) {
                    assert_eq!(
                        (a.round, a.syncs_ok, a.syncs_failed),
                        (b.round, b.syncs_ok, b.syncs_failed),
                        "{policy} {sync_mode:?}: replayed schedule diverged"
                    );
                }
                let served = |r: &sim::RunResult| -> Vec<u64> {
                    r.worker_stats.iter().map(|s| s.0).collect()
                };
                assert_eq!(served(&reference), served(&replayed));
            } else {
                assert_eq!(
                    digest(&reference),
                    digest(&replayed),
                    "{policy} {sync_mode:?}: trace replay is not byte-identical"
                );
            }
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// A longer recording truncates cleanly to a shorter run; a worker-count
/// mismatch is a hard error naming both counts.
#[test]
fn trace_truncates_to_shorter_runs_and_rejects_wrong_arity() {
    let base = quad_cfg();
    let trace =
        TraceFile::capture(&base.failure, base.seed, base.workers, base.rounds).unwrap();
    let path = tmp_trace("truncate");
    trace.save(&path).unwrap();

    let mut short = base.clone();
    short.rounds = 10;
    short.failure = FailureModel::Trace { path: path.clone() };
    let r = sim::run(&short).unwrap();
    assert_eq!(r.log.records.len(), 10);
    // the realized digest covers the truncated 10-round window, so it
    // deliberately differs from the 24-round file's digest
    assert_ne!(r.fault_digest, trace.table.digest());

    let mut fat = base.clone();
    fat.workers = 4;
    fat.failure = FailureModel::Trace { path: path.clone() };
    let err = format!("{:#}", sim::run(&fat).unwrap_err());
    assert!(err.contains("3 workers") && err.contains("4"), "{err}");

    let mut long = base.clone();
    long.rounds = 100;
    long.failure = FailureModel::Trace { path: path.clone() };
    let err = format!("{:#}", sim::run(&long).unwrap_err());
    assert!(err.contains("covers 24 rounds"), "{err}");
    let _ = std::fs::remove_file(&path);
}

/// Straggler regime (NO kills): a worker at one-third speed participates in
/// one round of three, which (a) skews the per-worker served-sync totals,
/// (b) changes the virtual clock's wait stream vs the uniform run, and
/// (c) is visible to the staleness-aware policies through `missed` — the
/// `delayed` policy teleports the stale replica (h1=1) where `fixed` keeps
/// h1=α always.
#[test]
fn stragglers_skew_participation_waits_and_policy_response() {
    let mut uniform = quad_cfg();
    uniform.failure = FailureModel::None;
    uniform.policy = Some("fixed(alpha=0.1)".to_string());
    let mut straggler = uniform.clone();
    straggler.speeds = Some(vec![1.0, 1.0, 3.0]);

    let u = sim::run(&uniform).unwrap();
    let s = sim::run(&straggler).unwrap();

    // (a) nonuniform participation: worker 2 served ~1/3 of the others
    let served: Vec<u64> = s.worker_stats.iter().map(|w| w.0).collect();
    assert_eq!(served[0], served[1], "full-speed workers stay uniform");
    assert!(
        served[2] <= served[0] / 2,
        "straggler must serve at most half the syncs of a full-speed worker, \
         got {served:?}"
    );
    // straggler rounds count as failed syncs even with FailureModel::None
    let failed: u32 = s.log.records.iter().map(|r| r.syncs_failed).sum();
    assert!(failed > 0, "straggler misses must surface as syncs_failed");
    let u_failed: u32 = u.log.records.iter().map(|r| r.syncs_failed).sum();
    assert_eq!(u_failed, 0, "uniform no-failure run has nothing to miss");

    // (b) the wait stream is nonuniform vs the uniform run
    assert!(
        s.sim.mean_sync_wait != u.sim.mean_sync_wait
            || s.sim.p95_style_max_wait != u.sim.p95_style_max_wait,
        "straggler run must change the sync-wait behaviour \
         (uniform mean={} p95={}, straggler mean={} p95={})",
        u.sim.mean_sync_wait,
        u.sim.p95_style_max_wait,
        s.sim.mean_sync_wait,
        s.sim.p95_style_max_wait
    );
    // and the straggler's compute stretches the virtual round span
    assert!(s.sim.virtual_secs > u.sim.virtual_secs);

    // (c) fixed never moves h1 off α; delayed teleports at the staleness cap
    let max_h1 = |r: &sim::RunResult| -> f64 {
        r.log
            .records
            .iter()
            .filter(|rec| rec.syncs_ok > 0)
            .map(|rec| rec.mean_h1)
            .fold(f64::MIN, f64::max)
    };
    assert!(
        (max_h1(&s) - 0.1).abs() < 1e-12,
        "fixed policy must keep h1=alpha even under stragglers, got {}",
        max_h1(&s)
    );
    let mut delayed = straggler.clone();
    delayed.policy = Some("delayed(alpha=0.1,staleness_cap=2)".to_string());
    let d = sim::run(&delayed).unwrap();
    assert!(
        max_h1(&d) > 0.3,
        "delayed policy must teleport the stale straggler (h1=1 lifts the \
         round mean), got max mean_h1 {}",
        max_h1(&d)
    );
    // adaptive responds too: its weighting under stragglers differs from
    // its uniform-regime weighting (where no syncs are ever missed)
    let mut adaptive_uniform = uniform.clone();
    adaptive_uniform.policy = Some("adaptive(alpha0=0.1,window=4)".to_string());
    let mut adaptive_straggler = adaptive_uniform.clone();
    adaptive_straggler.speeds = Some(vec![1.0, 1.0, 3.0]);
    let au = sim::run(&adaptive_uniform).unwrap();
    let asg = sim::run(&adaptive_straggler).unwrap();
    let h1_series = |r: &sim::RunResult| -> Vec<u64> {
        r.log.records.iter().map(|rec| rec.mean_h1.to_bits()).collect()
    };
    assert_ne!(
        h1_series(&au),
        h1_series(&asg),
        "adaptive must respond to straggler-induced misses"
    );
}

/// Membership + speeds are fingerprint axes: flipping either changes the
/// schedule fingerprint, and omitting them keeps legacy fingerprints.
#[test]
fn scenario_axes_change_fingerprints() {
    use deahes::schedule::TrialPlan;
    let fp = |cfg: &ExperimentConfig| -> String {
        let mut plan = TrialPlan::new();
        plan.push_cell("c", "c", cfg, 1);
        plan.slots[0].fingerprint.clone()
    };
    let base = quad_cfg();
    let legacy = fp(&base);
    let mut speeds = base.clone();
    speeds.speeds = Some(vec![1.0, 1.0, 3.0]);
    let mut membership = base.clone();
    membership.membership = Some("2=0-9+15-".to_string());
    assert_ne!(fp(&speeds), legacy);
    assert_ne!(fp(&membership), legacy);
    assert_ne!(fp(&speeds), fp(&membership));
}

/// Elastic membership end to end: the scheduled worker leaves, the run
/// carries on with the remaining workers, and the rejoin adopts the master
/// estimate — per-round sync arithmetic proves the window was honoured.
#[test]
fn membership_windows_gate_participation() {
    let mut cfg = quad_cfg();
    cfg.failure = FailureModel::None;
    // worker 2 active rounds 0..=9 and 15.., absent 10..=14
    cfg.membership = Some("2=0-9+15-".to_string());
    let r = sim::run(&cfg).unwrap();
    for rec in &r.log.records {
        let expect = if (10..=14).contains(&rec.round) { 2 } else { 3 };
        assert_eq!(
            rec.syncs_ok + rec.syncs_failed,
            expect,
            "round {}: absent workers must neither sync nor fail",
            rec.round
        );
    }
    // threaded drivers honour the identical window (fixed report arity
    // keeps the barrier protocol intact while worker 2 is away)
    for sync_mode in [SyncMode::Central, SyncMode::Gossip] {
        let mut thr = cfg.clone();
        thr.threaded = true;
        thr.sync_mode = sync_mode;
        let t = sim::run(&thr).unwrap();
        assert_eq!(t.log.records.len(), r.log.records.len());
        for (a, b) in r.log.records.iter().zip(&t.log.records) {
            assert_eq!(
                a.syncs_ok + a.syncs_failed,
                b.syncs_ok + b.syncs_failed,
                "{sync_mode:?}: threaded membership diverged at round {}",
                a.round
            );
        }
    }
}

/// Checkpoint/resume byte-identity across membership transitions: cuts
/// before the leave, inside the gap, and after the rejoin all continue to
/// the same bytes as the uninterrupted run — in central AND gossip mode.
#[test]
fn membership_transition_checkpoint_resume_is_byte_identical() {
    for sync_mode in [SyncMode::Central, SyncMode::Gossip] {
        let mut cfg = quad_cfg();
        cfg.failure = FailureModel::None;
        cfg.sync_mode = sync_mode;
        cfg.policy = Some("delayed(alpha=0.1,staleness_cap=3)".to_string());
        // transitions at round 10 (leave) and 15 (rejoin); cuts at 6
        // (before), 12 (inside the gap) and 18 (after the rejoin)
        cfg.membership = Some("2=0-9+15-".to_string());
        let baseline = digest(&sim::run(&cfg).unwrap());

        let mut cps: Vec<RunCheckpoint> = Vec::new();
        let mut save = |cp: RunCheckpoint| -> anyhow::Result<()> {
            cps.push(cp);
            Ok(())
        };
        let hooked = sim::run_with(
            &cfg,
            None,
            Some(CheckpointHooks { every: 6, every_secs: 0.0, save: &mut save }),
        )
        .unwrap();
        assert_eq!(
            digest(&hooked),
            baseline,
            "{sync_mode:?}: capturing checkpoints changed numbers"
        );
        assert_eq!(cps.len(), 3, "{sync_mode:?}: rounds=24, every=6 -> cuts at 6, 12, 18");
        for cp in &cps {
            let round = cp.next_round;
            let resumed = sim::run_with(&cfg, Some(cp), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{sync_mode:?}: resume from round {round} diverged across a \
                 membership transition"
            );
            // and through the JSON round-trip the sink actually stores
            let reread = RunCheckpoint::from_json(
                &Json::parse(&cp.to_json().to_string_compact()).unwrap(),
            )
            .unwrap();
            let resumed = sim::run_with(&cfg, Some(&reread), None).unwrap();
            assert_eq!(
                digest(&resumed),
                baseline,
                "{sync_mode:?}: resume from persisted round-{round} checkpoint diverged"
            );
        }
    }
}

/// Combined scenario: stragglers + membership + a recorded trace all at
/// once, checkpoint/resume included — the axes compose.
#[test]
fn combined_scenario_resumes_byte_identically() {
    let base = quad_cfg();
    let trace =
        TraceFile::capture(&base.failure, base.seed, base.workers, base.rounds).unwrap();
    let path = tmp_trace("combined");
    trace.save(&path).unwrap();

    let mut cfg = base.clone();
    cfg.failure = FailureModel::Trace { path: path.clone() };
    cfg.speeds = Some(vec![1.0, 2.0, 1.0]);
    cfg.membership = Some("0=0-11+18-".to_string());
    cfg.policy = Some("adaptive(alpha0=0.1,window=4)".to_string());
    let baseline = digest(&sim::run(&cfg).unwrap());

    let mut cps: Vec<RunCheckpoint> = Vec::new();
    let mut save = |cp: RunCheckpoint| -> anyhow::Result<()> {
        cps.push(cp);
        Ok(())
    };
    sim::run_with(
        &cfg,
        None,
        Some(CheckpointHooks { every: 8, every_secs: 0.0, save: &mut save }),
    )
    .unwrap();
    assert_eq!(cps.len(), 2);
    for cp in &cps {
        let resumed = sim::run_with(&cfg, Some(cp), None).unwrap();
        assert_eq!(
            digest(&resumed),
            baseline,
            "combined scenario: resume from round {} diverged",
            cp.next_round
        );
    }
    let _ = std::fs::remove_file(&path);
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deahes-scenario-{}-{name}", std::process::id()))
}

/// Committed records carry the realized-schedule digest: a burst run and
/// its trace replay are provably paired by inspecting runs.jsonl alone,
/// while a no-failure record omits the key entirely.
#[test]
fn committed_records_carry_the_fault_digest() {
    use deahes::schedule::{self, JsonlRunSink, ScheduleOptions, TrialPlan};
    let base = quad_cfg();
    let trace =
        TraceFile::capture(&base.failure, base.seed, base.workers, base.rounds).unwrap();
    let path = tmp_trace("records");
    trace.save(&path).unwrap();
    let expect = deahes::util::bits::u64_hex(trace.table.digest());

    let mut replay = base.clone();
    replay.failure = FailureModel::Trace { path: path.clone() };
    let mut clean = base.clone();
    clean.failure = FailureModel::None;

    let dir = tmp_dir("records");
    let _ = std::fs::remove_dir_all(&dir);
    let mut plan = TrialPlan::new();
    plan.push_cell("sc/burst", "burst", &base, 1);
    plan.push_cell("sc/replay", "replay", &replay, 1);
    plan.push_cell("sc/clean", "clean", &clean, 1);
    let opts = ScheduleOptions { run_dir: Some(dir.clone()), ..ScheduleOptions::default() };
    schedule::execute_plan(&plan, &opts).unwrap();

    let records = JsonlRunSink::load(&dir.join(schedule::RUNS_FILE)).unwrap();
    let by_cell = |cell: &str| {
        records.values().find(|r| r.cell == cell).expect("cell committed")
    };
    assert_eq!(by_cell("sc/burst").fault_digest.as_deref(), Some(expect.as_str()));
    assert_eq!(by_cell("sc/replay").fault_digest.as_deref(), Some(expect.as_str()));
    let clean_rec = by_cell("sc/clean");
    assert_eq!(clean_rec.fault_digest, None);
    assert!(
        !clean_rec.to_json().to_string_compact().contains("fault_digest"),
        "no-failure records must omit the key (legacy bytes)"
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_file(&path);
}
