//! Allocation regression: the quad-engine steady-state round is heap-free.
//!
//! A counting global allocator tallies every `alloc`/`realloc` made while a
//! thread-local tracking flag is set. The test warms the full coordinator
//! round (fused worker steps through the scratch arena, gossip estimate,
//! score pipeline, policy decision, elastic sync, snapshot publish) until
//! every buffer has reached steady state — scratch sized, score ring at
//! capacity, snapshot pool saturated — then asserts that further rounds
//! allocate NOTHING. Any hot-path regression (a fresh `Vec` per gradient, a
//! per-sync `theta.clone()`, a growing ring) trips this immediately.
//!
//! Scope: the steady-state round loop itself. Evaluation/metrics rounds may
//! allocate (amortized `MetricsLog` growth) and are exercised elsewhere.

use deahes::config::GossipMode;
use deahes::coordinator::gossip::GossipBoard;
use deahes::coordinator::master::MasterState;
use deahes::coordinator::worker::WorkerState;
use deahes::elastic::policy::{self, SyncContext};
use deahes::elastic::score::geometric_weights;
use deahes::engine::quad::QuadraticEngine;
use deahes::optim::{OptState, Optimizer};
use deahes::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

std::thread_local! {
    static TRACK: Cell<bool> = const { Cell::new(false) };
}

struct CountingAllocator;

// SAFETY: a pure pass-through to `System` — every method forwards its
// arguments unchanged, so `System`'s GlobalAlloc guarantees (layout
// fidelity, pointer validity) carry over; the counter bump is side-effect
// free for the allocator contract (atomic, no allocation, no reentrancy —
// `try_with` returns an Err instead of touching a dead thread-local).
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: caller upholds GlobalAlloc's layout contract; forwarded to
    // `System.alloc` verbatim.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if TRACK.try_with(|t| t.get()).unwrap_or(false) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    // SAFETY: `ptr` was produced by `alloc`/`realloc` above, which return
    // `System` pointers — freeing them through `System.dealloc` is sound.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: same pass-through argument as `alloc`/`dealloc`: `System`
    // both produced `ptr` and performs the resize.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if TRACK.try_with(|t| t.get()).unwrap_or(false) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

/// Count allocations made by `f` on this thread.
fn count_allocs<F: FnOnce()>(f: F) -> u64 {
    TRACK.with(|t| t.set(true));
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    let after = ALLOCS.load(Ordering::Relaxed);
    TRACK.with(|t| t.set(false));
    after - before
}

/// One full communication round over the coordinator state machines —
/// exactly the work `run_sequential` does per round, minus eval/metrics.
#[allow(clippy::too_many_arguments)]
fn round(
    engine: &mut QuadraticEngine,
    workers: &mut [WorkerState],
    master: &mut MasterState,
    gossip: &GossipBoard,
    order_rng: &mut Rng,
    gossip_rng: &mut Rng,
    order: &mut Vec<usize>,
    tau: usize,
    round_no: u64,
) {
    order_rng.permutation_into(order, workers.len());
    for &w in order.iter() {
        workers[w].local_round(engine, tau).unwrap();
        let (_, est) = gossip.estimate(w, gossip_rng);
        let score = workers[w].observe_and_score(&est);
        let mut tw = std::mem::take(&mut workers[w].theta);
        let ctx = SyncContext {
            worker: w,
            round: round_no,
            raw_score: score,
            missed: workers[w].missed,
            alpha: 0.1,
        };
        master.serve_sync(engine, &ctx, &mut tw).unwrap();
        workers[w].complete_sync(tw);
        gossip.publish(w, round_no + 1, master.publish_snapshot());
    }
}

fn build(k: usize, n: usize, opt: Optimizer) -> (
    QuadraticEngine,
    Vec<WorkerState>,
    MasterState,
    GossipBoard,
    Rng,
    Rng,
) {
    let engine = QuadraticEngine::new(n, 77, 0, 0.2, 0.02);
    let workers: Vec<WorkerState> = (0..k)
        .map(|i| {
            WorkerState::new(
                i,
                vec![0.0; n],
                OptState::new(opt, n),
                0.05,
                None,
                geometric_weights(4, 0.5),
                Rng::new(77).derive(0x2AD).derive(i as u64),
            )
        })
        .collect();
    let master = MasterState::new(
        vec![0.0; n],
        policy::parse("dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)").unwrap(),
        k,
    );
    let gossip = GossipBoard::new(k, Arc::new(vec![0.0; n]), GossipMode::Peers);
    (engine, workers, master, gossip, Rng::new(1), Rng::new(2))
}

fn assert_steady_state_round_is_alloc_free(opt: Optimizer, label: &str) {
    let (k, n, tau) = (4, 256, 2);
    let (mut engine, mut workers, mut master, gossip, mut order_rng, mut gossip_rng) =
        build(k, n, opt);
    let mut order: Vec<usize> = Vec::with_capacity(k);
    // Warm-up: fills the score rings (p+1 entries), saturates the snapshot
    // pool, and settles every Vec at its final capacity.
    for r in 0..10u64 {
        round(
            &mut engine,
            &mut workers,
            &mut master,
            &gossip,
            &mut order_rng,
            &mut gossip_rng,
            &mut order,
            tau,
            r,
        );
    }
    let allocs = count_allocs(|| {
        for r in 10..15u64 {
            round(
                &mut engine,
                &mut workers,
                &mut master,
                &gossip,
                &mut order_rng,
                &mut gossip_rng,
                &mut order,
                tau,
                r,
            );
        }
    });
    assert_eq!(
        allocs, 0,
        "{label}: steady-state rounds must not allocate ({allocs} allocations in 5 rounds)"
    );
    // sanity: the run actually trained and synced
    assert!(master.total_syncs >= 15 * k as u64);
    assert!(workers.iter().all(|w| w.steps >= 15 * tau as u64));
}

// ---------------------------------------------------------------------------
// gossip (decentralized elastic-pull) sync mode
// ---------------------------------------------------------------------------

/// One gossip-mode communication round over the coordinator state machines —
/// exactly the work the sequential gossip driver does per round, minus
/// eval/metrics: fused local steps, score against the published master
/// snapshot, per-worker policy decision, in-place `elastic_pull`, replica
/// publish through a per-worker `SnapshotPool`, and the master's
/// end-of-round fold + snapshot publish.
#[allow(clippy::too_many_arguments)]
fn gossip_round(
    engine: &mut QuadraticEngine,
    workers: &mut [WorkerState],
    master: &mut MasterState,
    gossip: &GossipBoard,
    policies: &mut [Box<dyn deahes::elastic::policy::SyncPolicy>],
    pools: &mut [deahes::coordinator::master::SnapshotPool],
    order_rng: &mut Rng,
    order: &mut Vec<usize>,
    folds: &mut Vec<(usize, f64, f64)>,
    tau: usize,
    round_no: u64,
) {
    use deahes::optim::native;
    folds.clear();
    order_rng.permutation_into(order, workers.len());
    for &w in order.iter() {
        workers[w].local_round(engine, tau).unwrap();
        let (_, est) = gossip.master_estimate();
        let score = workers[w].observe_and_score(&est);
        let ctx = SyncContext {
            worker: w,
            round: round_no,
            raw_score: score,
            missed: workers[w].missed,
            alpha: 0.1,
        };
        let wts = policies[w].weights(&ctx);
        native::elastic_pull(&mut workers[w].theta, &est, wts.h1 as f32);
        workers[w].complete_pull();
        gossip.publish(w, round_no + 1, pools[w].publish(&workers[w].theta));
        folds.push((w, wts.h1, wts.h2));
    }
    folds.sort_unstable_by_key(|&(w, _, _)| w);
    for &(w, h1, h2) in folds.iter() {
        let (_, replica) = gossip.entry(w);
        master.absorb_gossip(w, &replica, h1, h2);
    }
    gossip.publish_master(round_no + 1, master.publish_snapshot());
}

/// Gossip-mode steady state is allocation-free too: the replica pools and
/// the master's snapshot pool saturate during warm-up, the per-worker
/// policy state (adaptive's rings) reaches capacity, and further rounds
/// allocate NOTHING.
fn assert_gossip_steady_state_round_is_alloc_free(
    opt: Optimizer,
    policy_spec: &str,
    label: &str,
) {
    let (k, n, tau) = (4, 256, 2);
    let (mut engine, mut workers, mut master, gossip, mut order_rng, _) = build(k, n, opt);
    let mut policies: Vec<Box<dyn deahes::elastic::policy::SyncPolicy>> = (0..k)
        .map(|_| {
            let mut p = policy::parse(policy_spec).unwrap();
            p.init(k);
            p
        })
        .collect();
    let mut pools: Vec<deahes::coordinator::master::SnapshotPool> =
        (0..k).map(|_| deahes::coordinator::master::SnapshotPool::new()).collect();
    let mut order: Vec<usize> = Vec::with_capacity(k);
    let mut folds: Vec<(usize, f64, f64)> = Vec::with_capacity(k);
    for r in 0..10u64 {
        gossip_round(
            &mut engine,
            &mut workers,
            &mut master,
            &gossip,
            &mut policies,
            &mut pools,
            &mut order_rng,
            &mut order,
            &mut folds,
            tau,
            r,
        );
    }
    let allocs = count_allocs(|| {
        for r in 10..15u64 {
            gossip_round(
                &mut engine,
                &mut workers,
                &mut master,
                &gossip,
                &mut policies,
                &mut pools,
                &mut order_rng,
                &mut order,
                &mut folds,
                tau,
                r,
            );
        }
    });
    assert_eq!(
        allocs, 0,
        "{label}: steady-state gossip rounds must not allocate ({allocs} in 5 rounds)"
    );
    assert!(master.total_syncs >= 15 * k as u64);
    assert!(workers.iter().all(|w| w.steps >= 15 * tau as u64));
}

#[test]
fn sgd_steady_state_round_allocates_nothing() {
    assert_steady_state_round_is_alloc_free(Optimizer::Sgd, "sgd");
}

#[test]
fn gossip_sgd_steady_state_round_allocates_nothing() {
    assert_gossip_steady_state_round_is_alloc_free(
        Optimizer::Sgd,
        "dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)",
        "gossip/sgd/dynamic",
    );
}

/// The AdamW preset and the stateful adaptive policy keep the invariant:
/// moment buffers live in `OptState`, the policy's rings are
/// capacity-reserved, and the pull is in place.
#[test]
fn gossip_adamw_adaptive_steady_state_round_allocates_nothing() {
    assert_gossip_steady_state_round_is_alloc_free(
        Optimizer::AdamW,
        "adaptive(alpha0=0.1,window=4)",
        "gossip/adamw/adaptive",
    );
}

#[test]
fn momentum_steady_state_round_allocates_nothing() {
    assert_steady_state_round_is_alloc_free(Optimizer::Momentum, "momentum");
}

#[test]
fn adahessian_steady_state_round_allocates_nothing() {
    assert_steady_state_round_is_alloc_free(Optimizer::AdaHessian, "adahessian");
}

/// The chunked-tier call sites keep the invariant when driven with a serial
/// chunker — the configuration every driver uses below `--par-threshold`,
/// and the one the allocation contract in `util::par` promises is a plain
/// inline loop. Fused chunked engine steps (block-keyed noise, per-block
/// loss slab) and the chunked elastic kernels allocate nothing at steady
/// state, across a dimension spanning several NOISE_BLOCK chunks.
#[test]
fn chunked_call_sites_with_a_serial_chunker_allocate_nothing() {
    use deahes::engine::{BatchRef, Engine, WorkerScratch};
    use deahes::optim::native;
    use deahes::util::par::Chunker;

    let n = 2100;
    let mut engine = QuadraticEngine::new(n, 5, 0, 0.2, 0.02);
    engine.set_intra_parallel(1); // chunked tier on, serial plan: inline dispatch
    let ck = Chunker::serial();
    let mut theta = vec![0.1f32; n];
    let mut master = vec![0.0f32; n];
    let mut scratch = WorkerScratch::new(n);
    let mut run = |rounds: u64| {
        for _ in 0..rounds {
            engine.sgd_step(&mut theta, BatchRef { x: &[], y1h: &[] }, 0.03, &mut scratch).unwrap();
            native::elastic_pull_chunked(&mut theta, &master, 0.1, &ck);
            native::elastic_absorb_chunked(&mut master, &theta, 0.1, &ck);
        }
    };
    run(5); // warm-up
    let allocs = count_allocs(|| run(5));
    assert_eq!(allocs, 0, "serial-chunker call sites must not allocate ({allocs} in 5 rounds)");
}

/// The counting harness itself works: an intentional allocation is seen.
#[test]
fn harness_detects_allocations() {
    let seen = count_allocs(|| {
        let v: Vec<u8> = Vec::with_capacity(1024);
        std::hint::black_box(&v);
    });
    assert!(seen >= 1, "counting allocator failed to observe a Vec allocation");
}
