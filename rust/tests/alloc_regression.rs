//! Allocation regression: the quad-engine steady-state round is heap-free.
//!
//! A counting global allocator tallies every `alloc`/`realloc` made while a
//! thread-local tracking flag is set. The test warms the full coordinator
//! round (fused worker steps through the scratch arena, gossip estimate,
//! score pipeline, policy decision, elastic sync, snapshot publish) until
//! every buffer has reached steady state — scratch sized, score ring at
//! capacity, snapshot pool saturated — then asserts that further rounds
//! allocate NOTHING. Any hot-path regression (a fresh `Vec` per gradient, a
//! per-sync `theta.clone()`, a growing ring) trips this immediately.
//!
//! Scope: the steady-state round loop itself. Evaluation/metrics rounds may
//! allocate (amortized `MetricsLog` growth) and are exercised elsewhere.

use deahes::config::GossipMode;
use deahes::coordinator::gossip::GossipBoard;
use deahes::coordinator::master::MasterState;
use deahes::coordinator::worker::WorkerState;
use deahes::elastic::policy::{self, SyncContext};
use deahes::elastic::score::geometric_weights;
use deahes::engine::quad::QuadraticEngine;
use deahes::optim::{OptState, Optimizer};
use deahes::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

std::thread_local! {
    static TRACK: Cell<bool> = const { Cell::new(false) };
}

struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if TRACK.try_with(|t| t.get()).unwrap_or(false) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if TRACK.try_with(|t| t.get()).unwrap_or(false) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

/// Count allocations made by `f` on this thread.
fn count_allocs<F: FnOnce()>(f: F) -> u64 {
    TRACK.with(|t| t.set(true));
    let before = ALLOCS.load(Ordering::Relaxed);
    f();
    let after = ALLOCS.load(Ordering::Relaxed);
    TRACK.with(|t| t.set(false));
    after - before
}

/// One full communication round over the coordinator state machines —
/// exactly the work `run_sequential` does per round, minus eval/metrics.
#[allow(clippy::too_many_arguments)]
fn round(
    engine: &mut QuadraticEngine,
    workers: &mut [WorkerState],
    master: &mut MasterState,
    gossip: &GossipBoard,
    order_rng: &mut Rng,
    gossip_rng: &mut Rng,
    order: &mut Vec<usize>,
    tau: usize,
    round_no: u64,
) {
    order_rng.permutation_into(order, workers.len());
    for &w in order.iter() {
        workers[w].local_round(engine, tau).unwrap();
        let (_, est) = gossip.estimate(w, gossip_rng);
        let score = workers[w].observe_and_score(&est);
        let mut tw = std::mem::take(&mut workers[w].theta);
        let ctx = SyncContext {
            worker: w,
            round: round_no,
            raw_score: score,
            missed: workers[w].missed,
            alpha: 0.1,
        };
        master.serve_sync(engine, &ctx, &mut tw).unwrap();
        workers[w].complete_sync(tw);
        gossip.publish(w, round_no + 1, master.publish_snapshot());
    }
}

fn build(k: usize, n: usize, opt: Optimizer) -> (
    QuadraticEngine,
    Vec<WorkerState>,
    MasterState,
    GossipBoard,
    Rng,
    Rng,
) {
    let engine = QuadraticEngine::new(n, 77, 0, 0.2, 0.02);
    let workers: Vec<WorkerState> = (0..k)
        .map(|i| {
            WorkerState::new(
                i,
                vec![0.0; n],
                OptState::new(opt, n),
                0.05,
                None,
                geometric_weights(4, 0.5),
                Rng::new(77).derive(0x2AD).derive(i as u64),
            )
        })
        .collect();
    let master = MasterState::new(
        vec![0.0; n],
        policy::parse("dynamic(alpha=0.1,knee=-0.05,detector=paper-sign)").unwrap(),
        k,
    );
    let gossip = GossipBoard::new(k, Arc::new(vec![0.0; n]), GossipMode::Peers);
    (engine, workers, master, gossip, Rng::new(1), Rng::new(2))
}

fn assert_steady_state_round_is_alloc_free(opt: Optimizer, label: &str) {
    let (k, n, tau) = (4, 256, 2);
    let (mut engine, mut workers, mut master, gossip, mut order_rng, mut gossip_rng) =
        build(k, n, opt);
    let mut order: Vec<usize> = Vec::with_capacity(k);
    // Warm-up: fills the score rings (p+1 entries), saturates the snapshot
    // pool, and settles every Vec at its final capacity.
    for r in 0..10u64 {
        round(
            &mut engine,
            &mut workers,
            &mut master,
            &gossip,
            &mut order_rng,
            &mut gossip_rng,
            &mut order,
            tau,
            r,
        );
    }
    let allocs = count_allocs(|| {
        for r in 10..15u64 {
            round(
                &mut engine,
                &mut workers,
                &mut master,
                &gossip,
                &mut order_rng,
                &mut gossip_rng,
                &mut order,
                tau,
                r,
            );
        }
    });
    assert_eq!(
        allocs, 0,
        "{label}: steady-state rounds must not allocate ({allocs} allocations in 5 rounds)"
    );
    // sanity: the run actually trained and synced
    assert!(master.total_syncs >= 15 * k as u64);
    assert!(workers.iter().all(|w| w.steps >= 15 * tau as u64));
}

#[test]
fn sgd_steady_state_round_allocates_nothing() {
    assert_steady_state_round_is_alloc_free(Optimizer::Sgd, "sgd");
}

#[test]
fn momentum_steady_state_round_allocates_nothing() {
    assert_steady_state_round_is_alloc_free(Optimizer::Momentum, "momentum");
}

#[test]
fn adahessian_steady_state_round_allocates_nothing() {
    assert_steady_state_round_is_alloc_free(Optimizer::AdaHessian, "adahessian");
}

/// The counting harness itself works: an intentional allocation is seen.
#[test]
fn harness_detects_allocations() {
    let seen = count_allocs(|| {
        let v: Vec<u8> = Vec::with_capacity(1024);
        std::hint::black_box(&v);
    });
    assert!(seen >= 1, "counting allocator failed to observe a Vec allocation");
}
