//! Facts → views: the observability layer end to end.
//!
//! `runs.jsonl` is the immutable fact log; `deahes report` / `deahes
//! watch` are read-only views over it and `deahes compact` is the one
//! sanctioned rewriter. The contracts pinned here (ISSUE 10 acceptance):
//!
//!  1. compacting a mixed run dir — committed records, a superseded and a
//!     live checkpoint, an identity-only scratch line, a crash-truncated
//!     tail — carries every committed record line byte-identical and
//!     leaves `load_with_checkpoints` equivalent before/after;
//!  2. `deahes resume` of a killed trial commits byte-identical records
//!     whether it runs from the original or the compacted run dir;
//!  3. the watch poller and the report aggregator read the same dirs the
//!     schedule layer writes, with no side effects on them.

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::coordinator::checkpoint::RunCheckpoint;
use deahes::coordinator::sim::{self, CheckpointHooks};
use deahes::experiments;
use deahes::report::{self, TrialState, WatchState, CHECKPOINTS_FILE};
use deahes::schedule::sink::{scan_lines, SinkLineKind};
use deahes::schedule::{
    self, JsonlRunSink, ScheduleOptions, TrialCheckpoint, TrialPlan, RUNS_FILE,
};
use deahes::strategies::Method;
use deahes::util::json::Json;
use std::io::Write as _;
use std::path::{Path, PathBuf};

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deahes-views-{}-{name}", std::process::id()))
}

fn quad_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 24, heterogeneity: 0.3, noise: 0.05 },
        method: Method::DeahesO,
        workers: 3,
        tau: 2,
        rounds: 30,
        eval_subset: 16,
        policy: Some("hysteresis(alpha=0.1,knee=-0.05,detector=paper-sign,hold=2)".into()),
        ..ExperimentConfig::default()
    }
}

fn one_cell_plan(cell: &str) -> TrialPlan {
    let mut plan = TrialPlan::new();
    plan.push_cell(cell, "cell", &quad_cfg(), 1);
    plan
}

/// Committed records as the sink persists them — the byte-identity unit.
fn record_lines(dir: &Path) -> Vec<String> {
    JsonlRunSink::load(&dir.join(RUNS_FILE))
        .unwrap()
        .values()
        .map(|r| r.to_json().to_string_compact())
        .collect()
}

/// Raw record *lines* straight off the file, original bytes.
fn raw_record_lines(dir: &Path) -> Vec<String> {
    scan_lines(&dir.join(RUNS_FILE))
        .unwrap()
        .into_iter()
        .filter(|l| matches!(l.kind, SinkLineKind::Record(_)))
        .map(|l| l.raw)
        .collect()
}

/// Real mid-trial cuts for the quad config (rounds 8, 16, 24).
fn captured_states() -> Vec<RunCheckpoint> {
    let cfg = quad_cfg();
    let mut cps: Vec<RunCheckpoint> = Vec::new();
    let mut save = |cp: RunCheckpoint| -> anyhow::Result<()> {
        cps.push(cp);
        Ok(())
    };
    sim::run_with(&cfg, None, Some(CheckpointHooks { every: 8, every_secs: 0.0, save: &mut save }))
        .unwrap();
    cps
}

fn checkpoint(fp: &str, state: RunCheckpoint) -> TrialCheckpoint {
    TrialCheckpoint {
        fingerprint: fp.into(),
        cell: "views/live".into(),
        label: "live".into(),
        seed_index: 0,
        config: quad_cfg(),
        every: 8,
        every_secs: 0.0,
        state,
    }
}

/// The mixed-run-dir pin: committed + superseded checkpoint + live
/// checkpoint + identity-only scratch + crash-truncated tail, compacted
/// with committed bytes preserved and the loader's world unchanged.
#[test]
fn compact_mixed_run_dir_preserves_committed_bytes_and_loader_equivalence() {
    let dir = tmp_dir("mixed");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join(RUNS_FILE);

    // One real committed trial (header + record line).
    schedule::execute_plan(
        &one_cell_plan("views/mixed"),
        &ScheduleOptions { run_dir: Some(dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    let committed_fp = record_lines(&dir);
    assert_eq!(committed_fp.len(), 1);
    let committed_fp = JsonlRunSink::load(&path).unwrap().keys().next().unwrap().clone();

    // Checkpoint lines through the real writer: one for the committed
    // trial (drop fodder), then a superseded and a live cut for an
    // uncommitted trial.
    let states = captured_states();
    assert_eq!(states.len(), 3, "rounds=30, every=8 -> cuts at 8, 16, 24");
    {
        let sink = JsonlRunSink::open(&path).unwrap();
        let w = sink.checkpoint_writer();
        w.append(&checkpoint(&committed_fp, states[0].clone())).unwrap();
        w.append(&checkpoint("live-trial", states[0].clone())).unwrap();
        w.append(&checkpoint("live-trial", states[1].clone())).unwrap();
    }
    // Identity-only scratch: a checkpoint line whose state is garbage but
    // whose coordinates decode (the "re-run from scratch" shape)...
    let mut garbled = checkpoint("scratch-trial", states[0].clone()).to_json();
    if let Json::Obj(m) = &mut garbled {
        m.insert("state".into(), Json::str("opaque-future-driver-blob"));
    }
    // ...and a crash-truncated tail, no trailing newline.
    {
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "{}", garbled.to_string_compact()).unwrap();
        f.write_all(br#"{"deahes_checkpoint":1,"fingerprint":"half"#).unwrap();
    }

    let before = JsonlRunSink::load_with_checkpoints(&path).unwrap();
    let raw_before = raw_record_lines(&dir);
    let bytes_before = std::fs::read(&path).unwrap();
    let live_raw = scan_lines(&path)
        .unwrap()
        .into_iter()
        .filter(|l| {
            matches!(&l.kind,
                SinkLineKind::Checkpoint { fingerprint: Some(fp), next_round: Some(8), .. }
                    if fp == "live-trial")
        })
        .map(|l| l.raw)
        .next()
        .expect("the superseded live-trial cut is scannable");

    // Dry run: plans and verifies, changes nothing.
    let dry = report::compact_run_dir(&dir, true).unwrap();
    assert!(dry.dry_run);
    assert_eq!(std::fs::read(&path).unwrap(), bytes_before, "--dry-run must not touch the file");
    assert!(!dir.join(CHECKPOINTS_FILE).exists(), "--dry-run must not write the sidecar");

    // The real thing.
    let done = report::compact_run_dir(&dir, false).unwrap();
    assert_eq!(done.records, 1);
    assert_eq!(done.checkpoints_dropped, 1, "the committed trial's checkpoint is dropped");
    assert_eq!(done.checkpoints_moved, 1, "the superseded live cut moves to the sidecar");
    assert_eq!(done.checkpoints_kept, 2, "the live cut and the scratch identity stay");
    assert!(done.bytes_after < done.bytes_before, "{done:?}");

    // Committed record lines byte-identical; loader world equivalent.
    assert_eq!(raw_record_lines(&dir), raw_before);
    let after = JsonlRunSink::load_with_checkpoints(&path).unwrap();
    assert_eq!(
        before.records.keys().collect::<Vec<_>>(),
        after.records.keys().collect::<Vec<_>>()
    );
    for (fp, r) in &before.records {
        assert_eq!(
            r.to_json().to_string_compact(),
            after.records[fp].to_json().to_string_compact()
        );
    }
    assert_eq!(after.checkpoints.len(), 1);
    assert_eq!(after.checkpoints["live-trial"].next_round(), 16, "latest cut survives");
    assert_eq!(after.scratch.len(), 1);
    assert!(after.scratch.contains_key("scratch-trial"));

    // Sidecar holds the superseded line verbatim; the crash tail is still
    // in the main file (now newline-terminated, still malformed).
    let side = std::fs::read_to_string(dir.join(CHECKPOINTS_FILE)).unwrap();
    assert_eq!(side, format!("{live_raw}\n"));
    let main = std::fs::read_to_string(&path).unwrap();
    assert!(main.ends_with("\"fingerprint\":\"half\n"), "crash tail stays in place");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance pin: kill a trial after its first checkpoint, compact a
/// copy of the run dir, resume both — committed records byte-identical to
/// each other and to an uninterrupted run.
#[test]
fn compact_then_resume_commits_byte_identical_records() {
    let clean_dir = tmp_dir("rt-clean");
    let crash_dir = tmp_dir("rt-crash");
    let compacted_dir = tmp_dir("rt-compacted");
    for d in [&clean_dir, &crash_dir, &compacted_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
    let plan = one_cell_plan("views/resume");

    schedule::execute_plan(
        &plan,
        &ScheduleOptions { run_dir: Some(clean_dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    let err = schedule::execute_plan(
        &plan,
        &ScheduleOptions {
            run_dir: Some(crash_dir.clone()),
            checkpoint_every: 8,
            crash_after_checkpoints: 1,
            ..ScheduleOptions::default()
        },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("crash injection"), "{err}");
    assert!(record_lines(&crash_dir).is_empty(), "the killed trial must not have committed");

    // Compact a copy of the crashed dir. Nothing is superseded yet (one
    // live checkpoint), so this is the degenerate-but-legal compaction.
    std::fs::create_dir_all(&compacted_dir).unwrap();
    std::fs::copy(crash_dir.join(RUNS_FILE), compacted_dir.join(RUNS_FILE)).unwrap();
    let done = report::compact_run_dir(&compacted_dir, false).unwrap();
    assert_eq!(
        (done.records, done.checkpoints_kept, done.checkpoints_moved, done.checkpoints_dropped),
        (0, 1, 0, 0),
        "{done:?}"
    );

    // `deahes resume` engine, original and compacted side by side.
    let r1 = experiments::resume_run_dir(&crash_dir, 1).unwrap();
    let r2 = experiments::resume_run_dir(&compacted_dir, 1).unwrap();
    assert_eq!((r1.committed, r1.finished), (0, 1));
    assert_eq!((r2.committed, r2.finished), (0, 1));
    let from_crash = record_lines(&crash_dir);
    let from_compacted = record_lines(&compacted_dir);
    assert_eq!(from_crash.len(), 1);
    assert_eq!(
        from_compacted, from_crash,
        "resume from the compacted dir must commit identical bytes"
    );
    assert_eq!(
        from_crash,
        record_lines(&clean_dir),
        "and both must match the uninterrupted run"
    );

    for d in [&clean_dir, &crash_dir, &compacted_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}

/// The read-only views over real run dirs: the watch poller tracks a trial
/// checkpointed → committed across a crash/resume, and the report
/// aggregator joins the two dirs by fingerprint with `identical = true`
/// (byte-identical resume is the previous test's guarantee).
#[test]
fn watch_and_report_track_a_run_dir_through_crash_and_resume() {
    let clean_dir = tmp_dir("wr-clean");
    let crash_dir = tmp_dir("wr-crash");
    let _ = std::fs::remove_dir_all(&clean_dir);
    let _ = std::fs::remove_dir_all(&crash_dir);
    let plan = one_cell_plan("views/wr");

    schedule::execute_plan(
        &plan,
        &ScheduleOptions { run_dir: Some(clean_dir.clone()), ..ScheduleOptions::default() },
    )
    .unwrap();
    let mut w = WatchState::new(&clean_dir);
    assert!(w.poll().unwrap(), "first poll over a committed run changes the map");
    assert_eq!(w.trials().len(), 1);
    let t = w.trials().values().next().unwrap();
    assert_eq!(t.cell, "views/wr");
    assert_eq!(t.state, TrialState::Committed { attempts: None });
    assert!(!w.poll().unwrap(), "no new bytes, no change");

    // Crash mid-trial: the poller reports the checkpoint cut...
    assert!(schedule::execute_plan(
        &plan,
        &ScheduleOptions {
            run_dir: Some(crash_dir.clone()),
            checkpoint_every: 8,
            crash_after_checkpoints: 1,
            ..ScheduleOptions::default()
        },
    )
    .is_err());
    let mut w = WatchState::new(&crash_dir);
    assert!(w.poll().unwrap());
    assert_eq!(
        w.trials().values().next().unwrap().state,
        TrialState::Checkpointed { next_round: 8 }
    );
    assert!(w.render().contains("checkpointed @ round 8"), "{}", w.render());

    // ...and sees the commit appear when the resume finishes it.
    experiments::resume_run_dir(&crash_dir, 1).unwrap();
    assert!(w.poll().unwrap());
    assert_eq!(
        w.trials().values().next().unwrap().state,
        TrialState::Committed { attempts: None }
    );

    // Cross-run report: same plan fingerprint in both dirs, identical.
    let rep = report::gather(&[clean_dir.clone(), crash_dir.clone()]).unwrap();
    assert_eq!(rep.runs.len(), 2);
    for run in &rep.runs {
        assert_eq!((run.committed, run.checkpointed, run.scratch), (1, 0, 0));
        assert_eq!(run.cells.len(), 1);
        assert_eq!(run.cells[0].cell, "views/wr");
        assert_eq!(run.cells[0].trials, 1);
        assert!(run.cells[0].tail_acc_mean.is_finite());
    }
    assert_eq!(rep.comparison.len(), 1);
    let row = &rep.comparison[0];
    assert_eq!(row.cell, "views/wr");
    assert!(row.identical, "crash/resume must not diverge from the clean run");
    assert!(row.tail_acc.iter().all(Option::is_some));

    // The JSON view is valid JSON naming itself (the CLI's validity gate).
    let back = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
    assert_eq!(back.get("report").as_str(), Some("runs"));
    assert_eq!(back.get("runs").as_arr().map(|a| a.len()), Some(2));
    let text = rep.render_text();
    assert!(text.contains("views/wr"), "{text}");
    assert!(text.contains("identical"), "{text}");

    // Views left the facts alone: both dirs still load exactly one record.
    assert_eq!(record_lines(&clean_dir).len(), 1);
    assert_eq!(record_lines(&crash_dir).len(), 1);

    let _ = std::fs::remove_dir_all(&clean_dir);
    let _ = std::fs::remove_dir_all(&crash_dir);
}
