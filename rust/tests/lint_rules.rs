//! `deahes lint` end-to-end coverage: per-rule fixtures (true positive
//! caught, allowlisted negative passes), `--rule` filtering, CLI exit
//! codes, and the self-scan pinning the live tree lint-clean — so a
//! contract violation fails `cargo test` even before the CI gate runs.

use deahes::analysis::{self, allowlist::Allowlist, rules::Finding};

fn lint(files: &[(&str, &str)], allow: &str, rule: Option<&str>) -> (Vec<Finding>, Vec<String>) {
    let sources: Vec<(String, String)> =
        files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
    let mut allowlist =
        if allow.is_empty() { Allowlist::empty() } else { Allowlist::parse(allow).unwrap() };
    let report = analysis::lint_sources(&sources, &mut allowlist, rule).unwrap();
    (report.findings, report.warnings)
}

// ---------------------------------------------------------------------------
// Fixtures per rule: positive caught with file:line + rule id, negative clean.
// ---------------------------------------------------------------------------

const UNDOC_UNSAFE: &str = "pub fn f(p: *mut u8) {\n    unsafe { *p = 0 };\n}\n";

#[test]
fn undocumented_unsafe_positive_names_file_line_and_rule() {
    let (hits, _) = lint(&[("src/bad.rs", UNDOC_UNSAFE)], "", None);
    assert_eq!(hits.len(), 1, "{hits:?}");
    let h = &hits[0];
    assert_eq!((h.rule, h.path.as_str(), h.line), ("undocumented-unsafe", "src/bad.rs", 2));
}

#[test]
fn undocumented_unsafe_accepts_safety_comment_and_safety_doc() {
    let above = "pub fn f(p: *mut u8) {\n    // SAFETY: caller passes a valid, exclusive p\n    unsafe { *p = 0 };\n}\n";
    let doc = "/// # Safety\n/// p must be valid and exclusive.\npub unsafe fn f(p: *mut u8) {\n    *p = 0;\n}\n";
    let multiline = "fn g(tp: &P) {\n    dispatch(&|start, end| {\n        // SAFETY: ranges are disjoint per task\n        let c = unsafe { tp.slice(start, end) };\n        use_it(c);\n    });\n}\n";
    let (hits, _) =
        lint(&[("src/a.rs", above), ("src/b.rs", doc), ("src/c.rs", multiline)], "", None);
    assert!(hits.is_empty(), "{hits:?}");
}

#[test]
fn unsafe_inside_comments_and_strings_is_ignored() {
    let src = "// this mentions unsafe in prose\nlet s = \"unsafe { }\";\nlet r = r#\"unsafe\"#;\n";
    let (hits, _) = lint(&[("src/a.rs", src)], "", None);
    assert!(hits.is_empty(), "{hits:?}");
}

#[test]
fn a_blank_line_detaches_the_safety_comment() {
    let src = "pub fn f(p: *mut u8) {\n    // SAFETY: stale, detached comment\n\n    unsafe { *p = 0 };\n}\n";
    let (hits, _) = lint(&[("src/a.rs", src)], "", None);
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert_eq!(hits[0].line, 4);
}

const HASHMAP_USE: &str = "use std::collections::HashMap;\npub fn f() -> HashMap<u32, u32> {\n    HashMap::new()\n}\n";

#[test]
fn nondeterministic_collections_scoped_to_order_sensitive_modules() {
    let (hits, _) = lint(
        &[
            ("src/schedule/extra.rs", HASHMAP_USE), // fingerprint-adjacent: flagged
            ("src/metrics/mod.rs", HASHMAP_USE),    // display-only: out of scope
        ],
        "",
        None,
    );
    assert!(!hits.is_empty());
    assert!(hits.iter().all(|h| h.path == "src/schedule/extra.rs"), "{hits:?}");
    assert!(hits.iter().all(|h| h.rule == "nondeterministic-collections"));
}

#[test]
fn nondeterministic_collections_allowlisted_negative_passes() {
    let allow = "[[allow]]\nrule = \"nondeterministic-collections\"\npath = \"src/schedule/extra.rs\"\nreason = \"order never serialized\"\n";
    let (hits, warnings) = lint(&[("src/schedule/extra.rs", HASHMAP_USE)], allow, None);
    assert!(hits.is_empty(), "{hits:?}");
    assert!(warnings.is_empty(), "entry matched, no stale warning expected: {warnings:?}");
}

#[test]
fn stale_allowlist_entries_warn() {
    let allow = "[[allow]]\nrule = \"wall-clock-in-core\"\npath = \"src/never/was.rs\"\nreason = \"gone\"\n";
    let (hits, warnings) = lint(&[("src/clean.rs", "pub fn ok() {}\n")], allow, None);
    assert!(hits.is_empty());
    assert_eq!(warnings.len(), 1, "{warnings:?}");
    assert!(warnings[0].contains("stale"), "{warnings:?}");
}

const WALL_CLOCK: &str = "pub fn t() -> u64 {\n    let t0 = std::time::Instant::now();\n    t0.elapsed().as_secs()\n}\n";

#[test]
fn wall_clock_forbidden_in_core_exempt_in_supervisor_tier() {
    let (hits, _) = lint(
        &[
            ("src/elastic/policy/extra.rs", WALL_CLOCK), // core: flagged
            ("src/schedule/proc/extra.rs", WALL_CLOCK),  // supervisor: exempt
            ("src/util/logging.rs", WALL_CLOCK),         // logging: exempt
            ("benches/extra.rs", WALL_CLOCK),            // bench target: exempt
        ],
        "",
        None,
    );
    assert_eq!(hits.len(), 1, "{hits:?}");
    assert_eq!(
        (hits[0].rule, hits[0].path.as_str(), hits[0].line),
        ("wall-clock-in-core", "src/elastic/policy/extra.rs", 2)
    );
}

#[test]
fn float_serialization_flags_decimal_routes_not_hex_blobs() {
    let sci = "pub fn s(x: f64) -> String {\n    format!(\"{:e}\", x)\n}\n";
    let precision = "pub fn s(x: f64) -> String {\n    format!(\"{:.17}\", x)\n}\n";
    let parse = "pub fn p(s: &str) -> f32 {\n    s.parse::<f32>().unwrap()\n}\n";
    let hex = "pub fn s(xs: &[f32]) -> String {\n    crate::util::bits::f32s_hex(xs)\n}\n";
    let (hits, _) = lint(
        &[
            ("src/schedule/checkpoint.rs", sci),
            ("src/schedule/record.rs", precision),
            ("src/coordinator/checkpoint.rs", parse),
            ("src/schedule/sink.rs", hex), // blessed path: clean
        ],
        "",
        None,
    );
    assert_eq!(hits.len(), 3, "{hits:?}");
    assert!(hits.iter().all(|h| h.rule == "float-serialization"));
    assert!(hits.iter().all(|h| h.line == 2), "{hits:?}");
}

#[test]
fn config_field_coverage_positive_and_negative() {
    // `beta` is serialized + sampled; `gamma` is missing from both paths.
    let config = "pub struct ExperimentConfig {\n    pub beta: Option<f64>,\n    pub gamma: Option<u32>,\n    pub workers: usize,\n}\nimpl ExperimentConfig {\n    pub fn to_json(&self) {\n        if let Some(b) = self.beta {\n            push((\"beta\", b));\n        }\n    }\n}\n";
    let sink = "pub fn config_schema_hash() -> String {\n    let mut cfg = ExperimentConfig::default();\n    cfg.beta = Some(0.5);\n    hash(cfg)\n}\n";
    let (hits, _) = lint(&[("src/config.rs", config), ("src/schedule/sink.rs", sink)], "", None);
    assert_eq!(hits.len(), 2, "{hits:?}");
    assert!(hits.iter().all(|h| h.rule == "config-field-coverage"));
    assert!(hits.iter().all(|h| h.message.contains("gamma")), "{hits:?}");
    assert!(hits.iter().any(|h| h.message.contains("to_json")), "{hits:?}");
    assert!(hits.iter().any(|h| h.message.contains("schema_hash")), "{hits:?}");
}

// ---------------------------------------------------------------------------
// --rule filtering
// ---------------------------------------------------------------------------

#[test]
fn rule_filter_runs_only_the_selected_rule() {
    // One file violating two rules at once.
    let src = "use std::collections::HashMap;\npub fn f(p: *mut u8) {\n    unsafe { *p = 0 };\n}\n";
    let files = [("src/schedule/extra.rs", src)];
    let (all, _) = lint(&files, "", None);
    assert!(all.iter().any(|h| h.rule == "undocumented-unsafe"));
    assert!(all.iter().any(|h| h.rule == "nondeterministic-collections"));
    let (only, _) = lint(&files, "", Some("undocumented-unsafe"));
    assert!(!only.is_empty());
    assert!(only.iter().all(|h| h.rule == "undocumented-unsafe"), "{only:?}");
}

#[test]
fn unknown_rule_id_is_an_error_naming_the_catalog() {
    let sources = vec![("src/a.rs".to_string(), "pub fn ok() {}\n".to_string())];
    let err = analysis::lint_sources(&sources, &mut Allowlist::empty(), Some("no-such-rule"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("no-such-rule"), "{err}");
    assert!(err.contains("undocumented-unsafe"), "{err}");
}

// ---------------------------------------------------------------------------
// Self-scan: the shipped tree is lint-clean and the allowlist is tight.
// ---------------------------------------------------------------------------

#[test]
fn self_scan_live_tree_is_clean_with_no_stale_allows() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::lint_tree(root, None).unwrap();
    assert!(report.findings.is_empty(), "live tree has lint findings:\n{}", report.render(true));
    assert!(report.warnings.is_empty(), "stale lint.toml entries:\n{}", report.render(false));
    // the scan actually covered the tree (src + benches + tests)
    assert!(report.files > 50, "suspiciously few files scanned: {}", report.files);
}

// ---------------------------------------------------------------------------
// CLI: exit codes and report shape through the real binary.
// ---------------------------------------------------------------------------

#[test]
fn cli_exits_nonzero_on_findings_and_zero_on_the_live_tree() {
    use std::process::Command;
    // A tiny violating tree under a scratch root.
    let dir = std::env::temp_dir().join(format!("deahes-lint-fixture-{}", std::process::id()));
    let src = dir.join("src");
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(src.join("bad.rs"), UNDOC_UNSAFE).unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_deahes"))
        .args(["lint", "--fix-hints", "--root"])
        .arg(&dir)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!out.status.success(), "lint must exit nonzero on findings:\n{stdout}");
    assert!(stdout.contains("src/bad.rs:2: [undocumented-unsafe]"), "{stdout}");
    assert!(stdout.contains("fix: "), "--fix-hints must print hints:\n{stdout}");

    // --rule filtering through the CLI: a rule the fixture does not violate.
    let out = Command::new(env!("CARGO_BIN_EXE_deahes"))
        .args(["lint", "--rule", "wall-clock-in-core", "--root"])
        .arg(&dir)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stdout));
    std::fs::remove_dir_all(&dir).ok();

    // The shipped tree is clean → exit 0 (same invocation CI gates on).
    let out = Command::new(env!("CARGO_BIN_EXE_deahes")).arg("lint").output().unwrap();
    assert!(
        out.status.success(),
        "shipped tree must be lint-clean:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}

/// Stale `lint.toml` entries only *warn* on a plain run (exit 0) but fail
/// under `--strict` — the mode CI uses, so the allowlist can't rot past
/// deleted files.
#[test]
fn cli_strict_fails_on_stale_allowlist_entries_plain_run_does_not() {
    use std::process::Command;
    let dir = std::env::temp_dir().join(format!("deahes-lint-strict-{}", std::process::id()));
    let src = dir.join("src");
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(src.join("clean.rs"), "pub fn ok() {}\n").unwrap();
    std::fs::write(
        dir.join("lint.toml"),
        "[[allow]]\nrule = \"wall-clock-in-core\"\npath = \"src/never/was.rs\"\nreason = \"gone\"\n",
    )
    .unwrap();

    let out = Command::new(env!("CARGO_BIN_EXE_deahes"))
        .args(["lint", "--root"])
        .arg(&dir)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "a warning alone must not fail a plain run:\n{stdout}");
    assert!(stdout.contains("warning:"), "{stdout}");

    let out = Command::new(env!("CARGO_BIN_EXE_deahes"))
        .args(["lint", "--strict", "--root"])
        .arg(&dir)
        .output()
        .unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!out.status.success(), "--strict must fail on the stale entry");
    assert!(stderr.contains("strict"), "{stderr}");
    std::fs::remove_dir_all(&dir).ok();

    // The shipped tree passes even under --strict (no stale entries).
    let out =
        Command::new(env!("CARGO_BIN_EXE_deahes")).args(["lint", "--strict"]).output().unwrap();
    assert!(
        out.status.success(),
        "shipped tree must be strict-clean:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}
