//! Sequential-vs-threaded parity in the decentralized gossip sync mode —
//! the mirror of `driver_parity.rs` for the second topology.
//!
//! Failure injection is a pure function of (seed, worker, round) and a
//! gossip-mode "sync" is a pull+publish with no master round-trip, so both
//! drivers must record the *identical* per-round pull schedule and the
//! master must fold the identical per-worker sync counts. Numerics differ
//! only through the per-thread engine noise streams (the threaded driver
//! builds one engine per worker), so accuracy agrees statistically, not
//! bitwise — exactly the central-mode contract.
//!
//! A central-vs-gossip smoke rides along: same config, same fault schedule,
//! both topologies must converge on the quadratic model under burst
//! failures, and their schedule fingerprints must differ (sync_mode is a
//! real config axis).

use deahes::config::{EngineKind, ExperimentConfig, SyncMode};
use deahes::coordinator::{sim, FailureModel};
use deahes::schedule::fingerprint;
use deahes::strategies::Method;

fn gossip_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 48, heterogeneity: 0.3, noise: 0.02 },
        workers: 3,
        tau: 2,
        rounds: 50,
        lr: 0.05,
        eval_subset: 8,
        eval_every: 1, // record every round so pull counts align 1:1
        failure: FailureModel::Burst { p_start: 0.2, mean_len: 5.0 },
        sync_mode: SyncMode::Gossip,
        ..ExperimentConfig::default()
    }
}

fn run_both(cfg: &ExperimentConfig) -> (sim::RunResult, sim::RunResult) {
    let seq = sim::run(cfg).unwrap();
    let mut threaded = cfg.clone();
    threaded.threaded = true;
    let thr = sim::run(&threaded).unwrap();
    (seq, thr)
}

#[test]
fn per_round_pull_counts_are_identical_across_drivers() {
    let (seq, thr) = run_both(&gossip_cfg());
    assert_eq!(seq.log.records.len(), thr.log.records.len());
    for (s, t) in seq.log.records.iter().zip(&thr.log.records) {
        assert_eq!(s.round, t.round);
        assert_eq!(
            (s.syncs_ok, s.syncs_failed),
            (t.syncs_ok, t.syncs_failed),
            "pull schedule diverged at round {}",
            s.round
        );
    }
    // the masters therefore folded the same number of replicas per worker
    let served_seq: Vec<u64> = seq.worker_stats.iter().map(|s| s.0).collect();
    let served_thr: Vec<u64> = thr.worker_stats.iter().map(|s| s.0).collect();
    assert_eq!(served_seq, served_thr);
    // and the policy-weight telemetry is populated in both drivers (every
    // round that served at least one pull records finite mean weights)
    for (name, r) in [("sequential", &seq), ("threaded", &thr)] {
        let with_pulls: Vec<_> =
            r.log.records.iter().filter(|rec| rec.syncs_ok > 0).collect();
        assert!(!with_pulls.is_empty(), "{name}: no round served a pull");
        for rec in with_pulls {
            assert!(rec.mean_h1.is_finite(), "{name} round {}: mean_h1 missing", rec.round);
            assert!(rec.mean_h2.is_finite(), "{name} round {}: mean_h2 missing", rec.round);
        }
    }
}

#[test]
fn final_accuracy_agrees_within_tolerance() {
    for method in [Method::DeahesO, Method::Easgd] {
        let mut cfg = gossip_cfg();
        cfg.method = method;
        let (seq, thr) = run_both(&cfg);
        let a_seq = seq.log.tail_acc(10);
        let a_thr = thr.log.tail_acc(10);
        assert!(
            (a_seq - a_thr).abs() < 0.25,
            "{}: sequential tail acc {a_seq} vs threaded {a_thr}",
            method.name()
        );
        // and both actually converged (loss halved)
        for (name, r) in [("sequential", &seq), ("threaded", &thr)] {
            let first = r.log.records.first().unwrap().test_loss;
            let last = r.log.records.last().unwrap().test_loss;
            assert!(
                last < 0.5 * first,
                "{} {name}: loss {first} -> {last} did not halve",
                method.name()
            );
        }
    }
}

/// Central-vs-gossip smoke: same config modulo `sync_mode`, same burst
/// fault schedule. Both topologies converge; the per-round sync/pull
/// schedule is identical (suppression does not depend on the topology);
/// the fingerprints differ.
#[test]
fn central_and_gossip_both_converge_under_bursts() {
    let gossip = gossip_cfg();
    let mut central = gossip.clone();
    central.sync_mode = SyncMode::Central;

    let rg = sim::run(&gossip).unwrap();
    let rc = sim::run(&central).unwrap();

    for (name, r) in [("central", &rc), ("gossip", &rg)] {
        let first = r.log.records.first().unwrap().test_loss;
        let last = r.log.records.last().unwrap().test_loss;
        assert!(
            last.is_finite() && last < 0.5 * first,
            "{name}: loss {first} -> {last} did not halve under bursts"
        );
    }
    // identical fault schedule -> identical per-round sync/pull counts
    for (c, g) in rc.log.records.iter().zip(&rg.log.records) {
        assert_eq!(
            (c.syncs_ok, c.syncs_failed),
            (g.syncs_ok, g.syncs_failed),
            "round {}: topology changed the fault schedule",
            c.round
        );
    }
    // sync_mode is a first-class fingerprint axis
    assert_ne!(
        fingerprint(&central, "cell", 0),
        fingerprint(&gossip, "cell", 0),
        "central and gossip configs must fingerprint distinctly"
    );
    // and the serialized configs round-trip the mode
    let back = ExperimentConfig::from_json(&gossip.to_json()).unwrap();
    assert_eq!(back.sync_mode, SyncMode::Gossip);
}

/// The two new policies and the AdamW preset run end-to-end in gossip mode
/// (threaded included), converging on the quad model.
#[test]
fn new_policies_and_adamw_run_end_to_end_in_gossip_mode() {
    for (policy, optimizer) in [
        ("delayed(alpha=0.1,staleness_cap=3)", None),
        ("adaptive(alpha0=0.1,window=4)", None),
        ("delayed(alpha=0.1,staleness_cap=3)", Some("adamw(lr=0.02)")),
    ] {
        for threaded in [false, true] {
            let mut cfg = gossip_cfg();
            cfg.rounds = 40;
            cfg.policy = Some(policy.into());
            cfg.optimizer = optimizer.map(|s| s.to_string());
            cfg.threaded = threaded;
            let r = sim::run(&cfg).unwrap();
            let first = r.log.records.first().unwrap().test_loss;
            let last = r.log.records.last().unwrap().test_loss;
            assert!(
                last.is_finite() && last < first,
                "{policy} optimizer={optimizer:?} threaded={threaded}: {first} -> {last}"
            );
        }
    }
}
