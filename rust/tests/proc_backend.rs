//! Integration: the out-of-process trial backend (`--backend proc`).
//!
//! The contract under test (docs/ARCHITECTURE.md, "Process backend &
//! failure injection"):
//!  * a plan executed through child worker processes commits records
//!    byte-identical to the sequential backend's;
//!  * a worker SIGKILLed mid-trial (fault injection) is relaunched from its
//!    latest checkpoint and still converges to the identical committed
//!    record;
//!  * a worker that exceeds its deadline or exhausts its retry budget
//!    surfaces a structured, classified error instead of wedging the sweep.
//!
//! These tests spawn real `deahes trial-worker` processes: the worker
//! binary is the crate's own bin target, resolved via CARGO_BIN_EXE (the
//! test harness executable is not `deahes` itself).

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::schedule::{
    self, BackendChoice, JsonlRunSink, KillSpec, ProcOptions, ScheduleOptions, TrialPlan,
};
use deahes::strategies::Method;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn quad_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 16, heterogeneity: 0.2, noise: 0.02 },
        workers: 2,
        rounds: 8,
        eval_subset: 8,
        ..ExperimentConfig::default()
    }
}

/// 2 overlap ratios × 2 seeds: the fig3-shaped grid from the acceptance
/// check, small enough that every test spawns at most a handful of
/// processes.
fn quad_plan() -> TrialPlan {
    let mut plan = TrialPlan::new();
    for &r in &[0.0, 0.25] {
        let mut cfg = quad_cfg();
        cfg.method = Method::EahesO;
        cfg.overlap_ratio = r;
        plan.push_cell(&format!("proc/r={r}"), &format!("r={r}"), &cfg, 2);
    }
    plan
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("deahes-procbackend-{}-{name}", std::process::id()))
}

/// Supervisor options pointing at the real `deahes` binary, with a short
/// backoff so retry tests stay fast.
fn proc_opts() -> ProcOptions {
    ProcOptions {
        worker_exe: Some(PathBuf::from(env!("CARGO_BIN_EXE_deahes"))),
        backoff_ms: 10,
        ..ProcOptions::default()
    }
}

/// fingerprint -> compact committed-record bytes for a run dir, with the
/// supervisor-only `perf` section stripped: telemetry (attempt counts,
/// retry latencies) is intentionally backend-dependent, everything else
/// must be byte-invariant.
fn record_bytes(dir: &Path) -> BTreeMap<String, String> {
    JsonlRunSink::load(&dir.join(schedule::RUNS_FILE))
        .unwrap()
        .into_iter()
        .map(|(fp, mut r)| {
            r.perf = None;
            (fp, r.to_json().to_string_compact())
        })
        .collect()
}

#[test]
fn proc_backend_commits_byte_identical_records_to_sequential() {
    let seq_dir = tmp_dir("seq");
    let proc_dir = tmp_dir("proc");
    let _ = std::fs::remove_dir_all(&seq_dir);
    let _ = std::fs::remove_dir_all(&proc_dir);

    let plan = quad_plan();
    let seq = schedule::execute_plan(
        &plan,
        &ScheduleOptions {
            backend: BackendChoice::Sequential,
            run_dir: Some(seq_dir.clone()),
            ..ScheduleOptions::default()
        },
    )
    .unwrap();
    let prc = schedule::execute_plan(
        &plan,
        &ScheduleOptions {
            jobs: 2,
            backend: BackendChoice::Proc,
            run_dir: Some(proc_dir.clone()),
            proc: proc_opts(),
            ..ScheduleOptions::default()
        },
    )
    .unwrap();
    assert_eq!(prc.backend, "proc");
    assert_eq!(seq.outcomes.len(), prc.outcomes.len());
    // In-memory outcomes agree in plan order...
    for (a, b) in seq.outcomes.iter().zip(&prc.outcomes) {
        assert_eq!(a.record.fingerprint, b.record.fingerprint, "plan order must match");
        // ...modulo the supervisor-only perf section: proc records carry
        // attempt telemetry, sequential records never do.
        assert!(a.record.perf.is_none(), "sequential backend writes no perf section");
        let perf = b.record.perf.as_ref().expect("proc backend stamps perf telemetry");
        assert_eq!(perf.get("attempts").as_f64(), Some(1.0), "clean run = one attempt");
        assert_eq!(perf.get("kills_absorbed").as_f64(), Some(0.0));
        let mut b_stripped = b.record.clone();
        b_stripped.perf = None;
        assert_eq!(
            a.record.to_json().to_string_compact(),
            b_stripped.to_json().to_string_compact(),
            "trial {} must be backend-invariant",
            a.record.fingerprint
        );
    }
    // ...and so do the committed bytes on disk.
    assert_eq!(record_bytes(&seq_dir), record_bytes(&proc_dir));
    let _ = std::fs::remove_dir_all(&seq_dir);
    let _ = std::fs::remove_dir_all(&proc_dir);
}

/// The acceptance pin: SIGKILL a worker after its first checkpoint; the
/// supervisor relaunches it from that checkpoint and the committed record
/// is byte-identical to an unkilled sequential run.
#[test]
fn sigkilled_worker_relaunches_from_checkpoint_byte_identically() {
    let seq_dir = tmp_dir("kill-seq");
    let proc_dir = tmp_dir("kill-proc");
    let _ = std::fs::remove_dir_all(&seq_dir);
    let _ = std::fs::remove_dir_all(&proc_dir);

    let plan = quad_plan();
    schedule::execute_plan(
        &plan,
        &ScheduleOptions {
            backend: BackendChoice::Sequential,
            run_dir: Some(seq_dir.clone()),
            ..ScheduleOptions::default()
        },
    )
    .unwrap();
    let mut opts = ScheduleOptions {
        jobs: 2,
        backend: BackendChoice::Proc,
        run_dir: Some(proc_dir.clone()),
        checkpoint_every: 3,
        proc: proc_opts(),
        ..ScheduleOptions::default()
    };
    opts.proc.inject_kill = vec![KillSpec { trial: 1, after: 1 }];
    let report = schedule::execute_plan(&plan, &opts).unwrap();
    assert_eq!(report.executed, plan.len(), "the killed trial still completes");
    // The absorbed SIGKILL shows up in the committed telemetry: one free
    // relaunch (injected kills never consume the retry budget).
    let killed =
        report.outcomes[1].record.perf.as_ref().expect("proc backend stamps perf telemetry");
    assert_eq!(killed.get("kills_absorbed").as_f64(), Some(1.0));
    assert_eq!(killed.get("attempts").as_f64(), Some(2.0), "kill + relaunch = two launches");
    assert_eq!(killed.get("crashes_absorbed").as_f64(), Some(0.0));
    assert_eq!(
        record_bytes(&seq_dir),
        record_bytes(&proc_dir),
        "a SIGKILLed+relaunched trial must commit the same bytes as an unkilled run"
    );
    let _ = std::fs::remove_dir_all(&seq_dir);
    let _ = std::fs::remove_dir_all(&proc_dir);
}

/// A worker past its deadline is killed, retried, and — once the budget is
/// spent — surfaces a structured failure naming the timeout instead of
/// wedging the supervisor loop.
#[test]
fn timeout_exhausts_retries_with_a_classified_error() {
    let mut plan = TrialPlan::new();
    plan.push_cell("proc/timeout", "timeout", &quad_cfg(), 1);
    let mut opts = ScheduleOptions {
        backend: BackendChoice::Proc,
        proc: proc_opts(),
        ..ScheduleOptions::default()
    };
    opts.proc.timeout_secs = 0.3;
    opts.proc.max_retries = 1;
    opts.proc.test_stall_ms = 5_000; // every attempt stalls well past the deadline
    let err = format!("{:#}", schedule::execute_plan(&plan, &opts).unwrap_err());
    assert!(err.contains("timed out"), "{err}");
    assert!(err.contains("failed after 2 attempt(s)"), "{err}");
}

/// Repeated worker crashes (exit code 1 via crash injection) consume the
/// retry budget — each attempt resuming further along from its checkpoints
/// — and the final error names the exit-code classification.
#[test]
fn crashing_worker_exhausts_retries_with_exit_code_classification() {
    let dir = tmp_dir("crash");
    let _ = std::fs::remove_dir_all(&dir);
    let mut plan = TrialPlan::new();
    plan.push_cell("proc/crash", "crash", &quad_cfg(), 1);
    let mut opts = ScheduleOptions {
        backend: BackendChoice::Proc,
        run_dir: Some(dir.clone()),
        checkpoint_every: 2,
        crash_after_checkpoints: 1,
        proc: proc_opts(),
        ..ScheduleOptions::default()
    };
    opts.proc.max_retries = 1;
    let err = format!("{:#}", schedule::execute_plan(&plan, &opts).unwrap_err());
    assert!(err.contains("exited with code 1"), "{err}");
    assert!(err.contains("crash injection"), "{err}");
    assert!(err.contains("failed after 2 attempt(s)"), "{err}");
    // The failed sweep left its checkpoints behind: the trial is resumable,
    // not lost.
    let contents =
        JsonlRunSink::load_with_checkpoints(&dir.join(schedule::RUNS_FILE)).unwrap();
    assert!(contents.records.is_empty());
    assert_eq!(contents.checkpoints.len(), 1, "checkpoints survive the failed sweep");
    let _ = std::fs::remove_dir_all(&dir);
}
