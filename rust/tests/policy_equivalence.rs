//! Equivalence regression for the sync-policy refactor.
//!
//! The closed `WeightPolicy` enum was replaced by the `SyncPolicy` trait +
//! spec registry. These tests pin that the refactor changed NOTHING for the
//! paper presets:
//!
//!  1. pointwise — for every (raw_score, missed) input, the trait policies
//!     compute bit-identical weights to the frozen pre-refactor enum
//!     (`elastic::weight::WeightPolicy`, kept as the reference);
//!  2. end-to-end — a seeded sequential run via the method preset (policy
//!     derived) is byte-identical to the same run via the explicit spec,
//!     for every method;
//!  3. fingerprint — preset-driven configs serialize without a `policy`
//!     key, so their schedule fingerprints equal the pre-refactor hashes;
//!  4. the two new policies (`hysteresis`, `staleness`) run end-to-end via
//!     the `policy` override and through the `policy_sweep` axis.

use deahes::config::{EngineKind, ExperimentConfig};
use deahes::coordinator::{sim, FailureModel};
use deahes::elastic::policy::{self, SyncContext};
use deahes::elastic::weight::{Detector, DynamicParams, WeightPolicy};
use deahes::experiments;
use deahes::schedule::fingerprint;
use deahes::strategies::ALL_METHODS;
use deahes::util::proptest;

fn quad_cfg() -> ExperimentConfig {
    ExperimentConfig {
        engine: EngineKind::Quadratic { dim: 48, heterogeneity: 0.3, noise: 0.02 },
        workers: 4,
        tau: 2,
        rounds: 40,
        lr: 0.05,
        eval_subset: 8,
        failure: FailureModel::Burst { p_start: 0.15, mean_len: 4.0 },
        ..ExperimentConfig::default()
    }
}

fn ctx(raw_score: Option<f64>, missed: u32, alpha: f64) -> SyncContext {
    SyncContext { worker: 0, round: 0, raw_score, missed, alpha }
}

/// (1) The trait policies are pointwise bit-identical to the enum arms over
/// randomized inputs — given identical decisions, the rest of the sync path
/// is shared code, so run-level equality follows.
#[test]
fn trait_policies_match_the_enum_pointwise() {
    proptest::check("trait == enum pointwise", 400, |g| {
        let alpha = g.f64(0.01, 0.9);
        let knee = -g.f64(1e-4, 1.0);
        let detector = if g.bool() { Detector::PaperSign } else { Detector::DriftSign };
        let raw_score = if g.bool() { Some(g.f64_edgy(-2.0, 2.0)) } else { None };
        let missed = g.usize(0, 5) as u32;
        let c = ctx(raw_score, missed, alpha);

        let mut fixed = policy::parse(&format!("fixed(alpha={alpha})")).unwrap();
        let w = fixed.weights(&c);
        assert_eq!(
            (w.h1, w.h2),
            WeightPolicy::Fixed { alpha }.weights(raw_score, missed)
        );

        let mut oracle = policy::parse(&format!("oracle(alpha={alpha})")).unwrap();
        let w = oracle.weights(&c);
        assert_eq!(
            (w.h1, w.h2),
            WeightPolicy::Oracle { alpha }.weights(raw_score, missed)
        );

        let spec = format!(
            "dynamic(alpha={alpha},knee={knee},detector={})",
            detector.name()
        );
        let mut dynamic = policy::parse(&spec).unwrap();
        let w = dynamic.weights(&c);
        let params = DynamicParams { alpha, knee, detector };
        assert_eq!(
            (w.h1, w.h2),
            WeightPolicy::Dynamic(params).weights(raw_score, missed),
            "{spec} raw_score={raw_score:?}"
        );
    });
}

/// (2) Preset-derived and explicit-spec runs are byte-identical for every
/// method on a seeded sequential run.
#[test]
fn preset_and_explicit_spec_runs_are_byte_identical() {
    for m in ALL_METHODS {
        let mut preset = quad_cfg();
        preset.method = m;
        assert!(preset.policy.is_none());
        let mut explicit = preset.clone();
        explicit.policy = Some(preset.effective_policy_spec());

        let a = sim::run(&preset).unwrap();
        let b = sim::run(&explicit).unwrap();
        assert_eq!(a.log.records.len(), b.log.records.len(), "{}", m.name());
        for (x, y) in a.log.records.iter().zip(&b.log.records) {
            assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{} r{}", m.name(), x.round);
            assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits());
            assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
            assert_eq!(x.mean_h1.to_bits(), y.mean_h1.to_bits());
            assert_eq!(x.mean_h2.to_bits(), y.mean_h2.to_bits());
            assert_eq!((x.syncs_ok, x.syncs_failed), (y.syncs_ok, y.syncs_failed));
        }
        assert_eq!(a.worker_stats, b.worker_stats, "{}", m.name());
    }
}

/// (3) A preset-driven config serializes with NO `policy` key, so its
/// schedule fingerprint is computed over exactly the pre-refactor JSON.
#[test]
fn preset_configs_keep_pre_refactor_fingerprints() {
    let cfg = quad_cfg();
    let json = cfg.to_json().to_string_compact();
    assert!(!json.contains("\"policy\""), "preset config JSON grew a policy key: {json}");
    // and the fingerprint only moves when the policy actually differs
    let fp_preset = fingerprint(&cfg, "cell", 0);
    let mut explicit = cfg.clone();
    explicit.policy = Some(cfg.effective_policy_spec());
    assert_ne!(
        fp_preset,
        fingerprint(&explicit, "cell", 0),
        "explicit specs are a distinct (new) axis value"
    );
}

/// (4a) The new policies run end-to-end through the `--policy` path under
/// node failures, converge, and actually exercise their mechanisms.
#[test]
fn hysteresis_and_staleness_run_end_to_end() {
    for spec in ["hysteresis(hold=3)", "staleness(alpha=0.1,halflife=2)"] {
        let mut cfg = quad_cfg();
        cfg.rounds = 80;
        cfg.policy = Some(spec.to_string());
        let r = sim::run(&cfg).unwrap();
        let first = r.log.records.first().unwrap().test_loss;
        let last = r.log.records.last().unwrap().test_loss;
        assert!(last.is_finite() && last < first, "{spec}: {first} -> {last}");
        let corrections: u64 = r.worker_stats.iter().map(|s| s.1).sum();
        assert!(corrections > 0, "{spec}: failure handling never fired under bursts");
    }
}

/// (4b) Policies sweep as a first-class axis through the schedule engine,
/// and the threaded driver accepts a policy override too.
#[test]
fn new_policies_are_sweepable_and_threaded_safe() {
    let mut base = quad_cfg();
    base.rounds = 12;
    let specs: Vec<String> = ["dynamic", "hysteresis(hold=2)", "staleness"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let out = experiments::policy_sweep(&base, &specs, 1).unwrap();
    assert_eq!(out.len(), 3);
    let labels: Vec<&str> = out.iter().map(|s| s.label.as_str()).collect();
    assert!(labels.contains(&"hysteresis(alpha=0.1,knee=-0.05,detector=paper-sign,hold=2)"));
    assert!(labels.contains(&"staleness(alpha=0.1,halflife=2)"));

    let mut threaded = base.clone();
    threaded.threaded = true;
    threaded.policy = Some("hysteresis(hold=2)".into());
    let r = sim::run(&threaded).unwrap();
    assert!(r.log.records.last().unwrap().test_loss.is_finite());
}

/// Registry invariant, pinned at the integration level for CI: every
/// registered policy's canonical spec survives parse → describe → parse.
#[test]
fn every_registered_policy_spec_roundtrips() {
    let specs = policy::default_specs();
    assert_eq!(specs.len(), policy::names().len());
    for spec in specs {
        let rebuilt = policy::parse(&spec).unwrap();
        assert_eq!(rebuilt.spec(), spec, "'{spec}' must be a parse fixed point");
        assert_eq!(policy::canonical(&spec).unwrap(), spec);
    }
    // the PR-5 policies are registered
    for name in ["delayed", "adaptive"] {
        assert!(policy::names().contains(&name), "'{name}' missing from the registry");
    }
}

/// Property: the new specs survive parse → describe → parse over their full
/// in-range parameter space, idempotently, and rebuild identical policies.
#[test]
fn property_delayed_and_adaptive_specs_roundtrip() {
    proptest::check("delayed/adaptive spec roundtrip", 200, |g| {
        let alpha = g.f64(1e-6, 1.0);
        let cap = g.usize(1, 40);
        let window = g.usize(1, 40);
        for s in [
            format!("delayed(alpha={alpha},staleness_cap={cap})"),
            format!("adaptive(alpha0={alpha},window={window})"),
        ] {
            let c1 = policy::canonical(&s).unwrap_or_else(|e| panic!("'{s}': {e}"));
            let c2 = policy::canonical(&c1).unwrap();
            assert_eq!(c1, c2, "canonicalization must be idempotent for '{s}'");
            // the rebuilt policy prints the same canonical spec
            assert_eq!(policy::parse(&c1).unwrap().spec(), c1);
        }
        // spelling variants (whitespace, argument order) collapse
        let spaced = format!(" delayed ( staleness_cap = {cap} , alpha = {alpha} ) ");
        assert_eq!(
            policy::canonical(&spaced).unwrap(),
            policy::canonical(&format!("delayed(alpha={alpha},staleness_cap={cap})")).unwrap()
        );
    });
}

/// Degenerate parameters of the PR-5 specs are parse errors with messages
/// naming the offending knob: `staleness_cap=0` (delayed never serves its
/// healthy branch), `window=0` (adaptive has no history), and AdamW betas
/// ≥ 1 (bias correction divides by zero).
#[test]
fn degenerate_new_specs_rejected() {
    use deahes::optim::OptimSpec;
    let err = policy::parse("delayed(staleness_cap=0)").unwrap_err().to_string();
    assert!(err.contains("staleness_cap"), "{err}");
    let err = policy::parse("adaptive(window=0)").unwrap_err().to_string();
    assert!(err.contains("window"), "{err}");
    for bad in ["adamw(beta1=1)", "adamw(beta2=1)", "adamw(beta1=1.001)"] {
        let err = OptimSpec::parse(bad).unwrap_err().to_string();
        assert!(err.contains("beta"), "'{bad}': {err}");
    }
    // the config layer surfaces all three rejections
    let mut cfg = quad_cfg();
    cfg.policy = Some("delayed(staleness_cap=0)".into());
    assert!(cfg.validate().is_err());
    cfg.policy = Some("adaptive(window=0)".into());
    assert!(cfg.validate().is_err());
    cfg.policy = None;
    cfg.optimizer = Some("adamw(beta1=1)".into());
    assert!(cfg.validate().is_err());
}

/// The new policies join the sweep axis like any other registered policy,
/// with canonical labels and distinct fingerprints.
#[test]
fn delayed_and_adaptive_are_sweepable() {
    let mut base = quad_cfg();
    base.rounds = 12;
    let specs: Vec<String> = ["delayed(staleness_cap=3)", "adaptive(window=4)"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let out = experiments::policy_sweep(&base, &specs, 1).unwrap();
    assert_eq!(out.len(), 2);
    let labels: Vec<&str> = out.iter().map(|s| s.label.as_str()).collect();
    assert!(labels.contains(&"delayed(alpha=0.1,staleness_cap=3)"), "{labels:?}");
    assert!(labels.contains(&"adaptive(alpha0=0.1,window=4)"), "{labels:?}");
}

/// The new policies converge end-to-end under burst failures and exercise
/// their correction mechanisms (delayed: a burst longer than the cap;
/// adaptive: any windowed miss history attenuates h2 below α₀).
#[test]
fn delayed_and_adaptive_run_end_to_end() {
    for spec in ["delayed(alpha=0.1,staleness_cap=3)", "adaptive(alpha0=0.1,window=4)"] {
        let mut cfg = quad_cfg();
        cfg.rounds = 80;
        cfg.failure = FailureModel::Burst { p_start: 0.2, mean_len: 5.0 };
        cfg.policy = Some(spec.to_string());
        let r = sim::run(&cfg).unwrap();
        let first = r.log.records.first().unwrap().test_loss;
        let last = r.log.records.last().unwrap().test_loss;
        assert!(last.is_finite() && last < first, "{spec}: {first} -> {last}");
        let corrections: u64 = r.worker_stats.iter().map(|s| s.1).sum();
        assert!(corrections > 0, "{spec}: failure handling never fired under bursts");
    }
}
